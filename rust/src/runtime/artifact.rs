//! Run-artifact contracts: `manifest.json` parsing (the cross-language
//! AOT contract) and the [`RunSnapshot`] telemetry archive entry.
//!
//! [`RunSnapshot`] is the first increment of the ROADMAP item-5
//! run-artifact store: it pins the on-disk JSON shape for the
//! observability data every archive entry will carry (phase wall-times
//! plus the fleet counter rollup of one completed run). Full
//! checkpointing — `StatePlane` + RNG cursors + metrics in a single
//! compressed, seekable file so long sweeps resume mid-run — is
//! deferred to that ROADMAP item; nothing here advertises it.

use crate::telemetry::TelemetrySummary;
use crate::util::json::{parse, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One tensor's shape/dtype.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Logical name (e.g. "wte", "tokens", "d_wte").
    pub name: String,
    /// Dimensions (empty = scalar).
    pub shape: Vec<usize>,
    /// "f32" or "s32".
    pub dtype: String,
}

impl TensorSpec {
    /// Number of elements.
    pub fn count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One AOT model.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// HLO text filename (relative to the artifacts dir).
    pub hlo: String,
    /// Ordered inputs.
    pub inputs: Vec<TensorSpec>,
    /// Ordered outputs (the HLO returns them as one tuple).
    pub outputs: Vec<TensorSpec>,
    /// Initial-parameter blob, when the model has trainable state:
    /// (filename, tensor count, total f32 elements).
    pub params: Option<(String, usize, usize)>,
    /// Free-form numeric metadata (e.g. vocab, seq_len).
    pub meta: BTreeMap<String, f64>,
}

impl ModelSpec {
    /// Parameter inputs = all inputs except the trailing data inputs;
    /// by convention the params blob covers a *prefix* of `inputs`.
    pub fn param_inputs(&self) -> &[TensorSpec] {
        match &self.params {
            Some((_, count, _)) => &self.inputs[..*count],
            None => &[],
        }
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Models by name.
    pub models: BTreeMap<String, ModelSpec>,
}

fn tensor_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("expected array of tensor specs"))?
        .iter()
        .map(|t| {
            let name = t
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("tensor missing name"))?
                .to_string();
            let shape = t
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("tensor {name} missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim in {name}")))
                .collect::<Result<Vec<_>>>()?;
            let dtype = t.get("dtype").and_then(Json::as_str).unwrap_or("f32").to_string();
            if dtype != "f32" && dtype != "s32" {
                bail!("unsupported dtype {dtype} for {name}");
            }
            Ok(TensorSpec { name, shape, dtype })
        })
        .collect()
}

impl Manifest {
    /// Load and validate `manifest.json` from the artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = parse(&text).map_err(|e| anyhow!("manifest parse error: {e}"))?;
        let version = root
            .get("format_version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing format_version"))?;
        if version != 1 {
            bail!("unsupported manifest format_version {version}");
        }
        let models_json = match root.get("models") {
            Some(Json::Obj(m)) => m,
            _ => bail!("manifest missing models object"),
        };
        let mut models = BTreeMap::new();
        for (name, m) in models_json {
            let hlo = m
                .get("hlo")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("model {name} missing hlo"))?
                .to_string();
            if !dir.join(&hlo).exists() {
                bail!("model {name}: HLO file {hlo} missing from {}", dir.display());
            }
            let inputs = tensor_specs(m.get("inputs").ok_or_else(|| anyhow!("no inputs"))?)?;
            let outputs = tensor_specs(m.get("outputs").ok_or_else(|| anyhow!("no outputs"))?)?;
            let params = match m.get("params") {
                Some(p) => {
                    let file = p
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("params missing file"))?
                        .to_string();
                    let count = p
                        .get("count")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("params missing count"))?;
                    let total = p
                        .get("total")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("params missing total"))?;
                    // Cross-validate against the declared input shapes.
                    let declared: usize = inputs[..count].iter().map(TensorSpec::count).sum();
                    if declared != total {
                        bail!("model {name}: params total {total} != input prefix {declared}");
                    }
                    Some((file, count, total))
                }
                None => None,
            };
            let mut meta = BTreeMap::new();
            if let Some(Json::Obj(mm)) = m.get("meta") {
                for (k, v) in mm {
                    if let Some(x) = v.as_f64() {
                        meta.insert(k.clone(), x);
                    }
                }
            }
            models.insert(name.clone(), ModelSpec { hlo, inputs, outputs, params, meta });
        }
        Ok(Self { models })
    }

    /// Fetch a model spec by name.
    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models.get(name).ok_or_else(|| anyhow!("model {name} not in manifest"))
    }
}

/// Read a flat little-endian f32 blob.
pub fn read_f32_blob(path: &Path, expected: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() != expected * 4 {
        bail!("{}: expected {} f32 ({} B), got {} B", path.display(), expected, expected * 4, bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Schema version of the [`RunSnapshot`] JSON surface.
pub const SNAPSHOT_VERSION: usize = 1;

/// A saved telemetry snapshot of one completed run — the archive-entry
/// contract of the run-artifact store.
///
/// This is deliberately *only* the observability rollup: the phase
/// wall-time rows plus the fleet counters of a
/// [`TelemetrySummary`], stamped with the rounds the engine completed.
/// It round-trips through the same hand-rolled JSON layer as the
/// manifest ([`crate::util::json`]), so Python-side tooling can read it
/// with `json.loads`. Full run checkpointing — `StatePlane` + RNG
/// cursors + metrics in a compressed, seekable archive so long sweeps
/// resume mid-run — is ROADMAP item 5 and is **not** provided here;
/// this type exists so the archive's telemetry column is pinned before
/// that work lands.
///
/// Phase names are owned `String`s (unlike
/// [`crate::telemetry::PhaseStat`]'s `&'static str`) because a loaded
/// snapshot cannot point into the engine's static phase tables.
/// Counters are stored as JSON numbers (f64), exact up to 2^53 — far
/// beyond any run this crate produces.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunSnapshot {
    /// Rounds the engine completed for this run.
    pub rounds_completed: usize,
    /// Phase rows as (name, accumulated wall seconds, span count), in
    /// the engine's table order.
    pub phases: Vec<(String, f64, u64)>,
    /// Sum of the phase wall seconds.
    pub total_phase_secs: f64,
    /// Fleet-total messages put on the wire.
    pub sends: u64,
    /// Fleet-total messages dropped by the loss model.
    pub drops: u64,
    /// Fleet-total mailbox supersedes.
    pub superseded: u64,
    /// Broadcasts delayed by a straggler schedule.
    pub straggler_delayed: u64,
    /// Fleet-total modeled payload bytes.
    pub modeled_bytes: u64,
    /// Fleet-total measured wire bytes.
    pub measured_bytes: u64,
    /// Payload-pool cells created across the engine's pools.
    pub fresh_payload_cells: u64,
}

impl RunSnapshot {
    /// Capture a snapshot from a run's harvested telemetry.
    pub fn from_summary(rounds_completed: usize, s: &TelemetrySummary) -> Self {
        Self {
            rounds_completed,
            phases: s
                .phases
                .iter()
                .map(|p| (p.name.to_string(), p.total_secs, p.count))
                .collect(),
            total_phase_secs: s.total_phase_secs,
            sends: s.sends,
            drops: s.drops,
            superseded: s.superseded,
            straggler_delayed: s.straggler_delayed,
            modeled_bytes: s.modeled_bytes,
            measured_bytes: s.measured_bytes,
            fresh_payload_cells: s.fresh_payload_cells,
        }
    }

    /// Serialize to the schema-v1 JSON text.
    pub fn to_json(&self) -> String {
        let mut obj = BTreeMap::new();
        obj.insert("snapshot_version".to_string(), Json::Num(SNAPSHOT_VERSION as f64));
        obj.insert("rounds_completed".to_string(), Json::Num(self.rounds_completed as f64));
        obj.insert("total_phase_secs".to_string(), Json::Num(self.total_phase_secs));
        let phases = self
            .phases
            .iter()
            .map(|(name, secs, count)| {
                let mut p = BTreeMap::new();
                p.insert("name".to_string(), Json::Str(name.clone()));
                p.insert("total_secs".to_string(), Json::Num(*secs));
                p.insert("count".to_string(), Json::Num(*count as f64));
                Json::Obj(p)
            })
            .collect();
        obj.insert("phases".to_string(), Json::Arr(phases));
        for (key, value) in [
            ("sends", self.sends),
            ("drops", self.drops),
            ("superseded", self.superseded),
            ("straggler_delayed", self.straggler_delayed),
            ("modeled_bytes", self.modeled_bytes),
            ("measured_bytes", self.measured_bytes),
            ("fresh_payload_cells", self.fresh_payload_cells),
        ] {
            obj.insert(key.to_string(), Json::Num(value as f64));
        }
        Json::Obj(obj).to_string()
    }

    /// Parse a schema-v1 snapshot back from JSON text.
    pub fn parse(text: &str) -> Result<Self> {
        let root = parse(text).map_err(|e| anyhow!("snapshot parse error: {e}"))?;
        let version = root
            .get("snapshot_version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("snapshot missing snapshot_version"))?;
        if version != SNAPSHOT_VERSION {
            bail!("unsupported snapshot_version {version}");
        }
        let field = |key: &str| -> Result<u64> {
            root.get(key)
                .and_then(Json::as_f64)
                .map(|x| x as u64)
                .ok_or_else(|| anyhow!("snapshot missing {key}"))
        };
        let phases = root
            .get("phases")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("snapshot missing phases"))?
            .iter()
            .map(|p| {
                let name = p
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("phase row missing name"))?
                    .to_string();
                let secs = p
                    .get("total_secs")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("phase {name} missing total_secs"))?;
                let count = p
                    .get("count")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("phase {name} missing count"))? as u64;
                Ok((name, secs, count))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            rounds_completed: root
                .get("rounds_completed")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("snapshot missing rounds_completed"))?,
            phases,
            total_phase_secs: root
                .get("total_phase_secs")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("snapshot missing total_phase_secs"))?,
            sends: field("sends")?,
            drops: field("drops")?,
            superseded: field("superseded")?,
            straggler_delayed: field("straggler_delayed")?,
            modeled_bytes: field("modeled_bytes")?,
            measured_bytes: field("measured_bytes")?,
            fresh_payload_cells: field("fresh_payload_cells")?,
        })
    }

    /// Write the snapshot to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json()).with_context(|| format!("writing {}", path.display()))
    }

    /// Load a snapshot previously written by [`RunSnapshot::save`].
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_real_manifest_when_present() {
        let dir = crate::runtime::artifacts_dir(None);
        if !crate::runtime::artifacts_available(&dir) {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        for name in ["quad", "logistic", "transformer", "quantize", "consensus"] {
            assert!(m.models.contains_key(name), "{name} missing");
        }
        let tr = m.model("transformer").unwrap();
        let (file, count, total) = tr.params.clone().unwrap();
        assert_eq!(tr.param_inputs().len(), count);
        assert_eq!(tr.inputs.last().unwrap().name, "tokens");
        assert_eq!(tr.inputs.last().unwrap().dtype, "s32");
        assert_eq!(tr.outputs.len(), count + 1);
        let blob = read_f32_blob(&dir.join(file), total).unwrap();
        assert_eq!(blob.len(), total);
    }

    #[test]
    fn rejects_bad_manifest() {
        let tmp = std::env::temp_dir().join("adcdgd_bad_manifest");
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("manifest.json"), "{\"format_version\": 2, \"models\": {}}")
            .unwrap();
        assert!(Manifest::load(&tmp).is_err());
        std::fs::write(tmp.join("manifest.json"), "not json").unwrap();
        assert!(Manifest::load(&tmp).is_err());
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn snapshot_round_trips_through_json() {
        let summary = TelemetrySummary {
            enabled: true,
            phases: vec![
                crate::telemetry::PhaseStat { name: "compress", total_secs: 0.25, count: 1920 },
                crate::telemetry::PhaseStat { name: "observe", total_secs: 0.01, count: 120 },
            ],
            total_phase_secs: 0.26,
            sends: 3840,
            drops: 378,
            superseded: 0,
            straggler_delayed: 7,
            modeled_bytes: 31_158,
            measured_bytes: 29_001,
            fresh_payload_cells: 48,
            node_rollups: vec![],
        };
        let snap = RunSnapshot::from_summary(120, &summary);
        let parsed = RunSnapshot::parse(&snap.to_json()).unwrap();
        assert_eq!(parsed, snap);
        assert_eq!(parsed.rounds_completed, 120);
        assert_eq!(parsed.phases[0], ("compress".to_string(), 0.25, 1920));
        assert_eq!(parsed.modeled_bytes, 31_158);

        let path = std::env::temp_dir().join("adcdgd_run_snapshot.json");
        snap.save(&path).unwrap();
        assert_eq!(RunSnapshot::load(&path).unwrap(), snap);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn snapshot_rejects_wrong_version_and_garbage() {
        let snap = RunSnapshot::from_summary(1, &TelemetrySummary::default());
        let bumped = snap.to_json().replace("\"snapshot_version\":1", "\"snapshot_version\":9");
        assert!(RunSnapshot::parse(&bumped).is_err());
        assert!(RunSnapshot::parse("not json").is_err());
        assert!(RunSnapshot::parse("{\"snapshot_version\": 1}").is_err());
    }

    #[test]
    fn tensor_spec_count() {
        let t = TensorSpec { name: "x".into(), shape: vec![3, 4], dtype: "f32".into() };
        assert_eq!(t.count(), 12);
        let s = TensorSpec { name: "s".into(), shape: vec![], dtype: "f32".into() };
        assert_eq!(s.count(), 1);
    }
}
