//! `manifest.json` parsing — the cross-language artifact contract.

use crate::util::json::{parse, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One tensor's shape/dtype.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Logical name (e.g. "wte", "tokens", "d_wte").
    pub name: String,
    /// Dimensions (empty = scalar).
    pub shape: Vec<usize>,
    /// "f32" or "s32".
    pub dtype: String,
}

impl TensorSpec {
    /// Number of elements.
    pub fn count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One AOT model.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// HLO text filename (relative to the artifacts dir).
    pub hlo: String,
    /// Ordered inputs.
    pub inputs: Vec<TensorSpec>,
    /// Ordered outputs (the HLO returns them as one tuple).
    pub outputs: Vec<TensorSpec>,
    /// Initial-parameter blob, when the model has trainable state:
    /// (filename, tensor count, total f32 elements).
    pub params: Option<(String, usize, usize)>,
    /// Free-form numeric metadata (e.g. vocab, seq_len).
    pub meta: BTreeMap<String, f64>,
}

impl ModelSpec {
    /// Parameter inputs = all inputs except the trailing data inputs;
    /// by convention the params blob covers a *prefix* of `inputs`.
    pub fn param_inputs(&self) -> &[TensorSpec] {
        match &self.params {
            Some((_, count, _)) => &self.inputs[..*count],
            None => &[],
        }
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Models by name.
    pub models: BTreeMap<String, ModelSpec>,
}

fn tensor_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("expected array of tensor specs"))?
        .iter()
        .map(|t| {
            let name = t
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("tensor missing name"))?
                .to_string();
            let shape = t
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("tensor {name} missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim in {name}")))
                .collect::<Result<Vec<_>>>()?;
            let dtype = t.get("dtype").and_then(Json::as_str).unwrap_or("f32").to_string();
            if dtype != "f32" && dtype != "s32" {
                bail!("unsupported dtype {dtype} for {name}");
            }
            Ok(TensorSpec { name, shape, dtype })
        })
        .collect()
}

impl Manifest {
    /// Load and validate `manifest.json` from the artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = parse(&text).map_err(|e| anyhow!("manifest parse error: {e}"))?;
        let version = root
            .get("format_version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing format_version"))?;
        if version != 1 {
            bail!("unsupported manifest format_version {version}");
        }
        let models_json = match root.get("models") {
            Some(Json::Obj(m)) => m,
            _ => bail!("manifest missing models object"),
        };
        let mut models = BTreeMap::new();
        for (name, m) in models_json {
            let hlo = m
                .get("hlo")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("model {name} missing hlo"))?
                .to_string();
            if !dir.join(&hlo).exists() {
                bail!("model {name}: HLO file {hlo} missing from {}", dir.display());
            }
            let inputs = tensor_specs(m.get("inputs").ok_or_else(|| anyhow!("no inputs"))?)?;
            let outputs = tensor_specs(m.get("outputs").ok_or_else(|| anyhow!("no outputs"))?)?;
            let params = match m.get("params") {
                Some(p) => {
                    let file = p
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("params missing file"))?
                        .to_string();
                    let count = p
                        .get("count")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("params missing count"))?;
                    let total = p
                        .get("total")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("params missing total"))?;
                    // Cross-validate against the declared input shapes.
                    let declared: usize = inputs[..count].iter().map(TensorSpec::count).sum();
                    if declared != total {
                        bail!("model {name}: params total {total} != input prefix {declared}");
                    }
                    Some((file, count, total))
                }
                None => None,
            };
            let mut meta = BTreeMap::new();
            if let Some(Json::Obj(mm)) = m.get("meta") {
                for (k, v) in mm {
                    if let Some(x) = v.as_f64() {
                        meta.insert(k.clone(), x);
                    }
                }
            }
            models.insert(name.clone(), ModelSpec { hlo, inputs, outputs, params, meta });
        }
        Ok(Self { models })
    }

    /// Fetch a model spec by name.
    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models.get(name).ok_or_else(|| anyhow!("model {name} not in manifest"))
    }
}

/// Read a flat little-endian f32 blob.
pub fn read_f32_blob(path: &Path, expected: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() != expected * 4 {
        bail!("{}: expected {} f32 ({} B), got {} B", path.display(), expected, expected * 4, bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_real_manifest_when_present() {
        let dir = crate::runtime::artifacts_dir(None);
        if !crate::runtime::artifacts_available(&dir) {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        for name in ["quad", "logistic", "transformer", "quantize", "consensus"] {
            assert!(m.models.contains_key(name), "{name} missing");
        }
        let tr = m.model("transformer").unwrap();
        let (file, count, total) = tr.params.clone().unwrap();
        assert_eq!(tr.param_inputs().len(), count);
        assert_eq!(tr.inputs.last().unwrap().name, "tokens");
        assert_eq!(tr.inputs.last().unwrap().dtype, "s32");
        assert_eq!(tr.outputs.len(), count + 1);
        let blob = read_f32_blob(&dir.join(file), total).unwrap();
        assert_eq!(blob.len(), total);
    }

    #[test]
    fn rejects_bad_manifest() {
        let tmp = std::env::temp_dir().join("adcdgd_bad_manifest");
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("manifest.json"), "{\"format_version\": 2, \"models\": {}}")
            .unwrap();
        assert!(Manifest::load(&tmp).is_err());
        std::fs::write(tmp.join("manifest.json"), "not json").unwrap();
        assert!(Manifest::load(&tmp).is_err());
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn tensor_spec_count() {
        let t = TensorSpec { name: "x".into(), shape: vec![3, 4], dtype: "f32".into() };
        assert_eq!(t.count(), 12);
        let s = TensorSpec { name: "s".into(), shape: vec![], dtype: "f32".into() };
        assert_eq!(s.count(), 1);
    }
}
