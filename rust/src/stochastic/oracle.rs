//! [`SampleOracle`] — seeded minibatch index blocks over one shard.
//!
//! The oracle owns a private [`Xoshiro256pp`] stream and yields
//! fixed-size index blocks that sweep the shard in *per-epoch random
//! permutations*: positions `[e·m, (e+1)·m)` of the emitted index
//! sequence cover every shard sample exactly once (shuffled sampling
//! without replacement, the standard SGD epoch discipline). Blocks may
//! straddle epoch boundaries when the batch size does not divide the
//! shard.
//!
//! ## Fixed-draw block contract
//!
//! Mirroring the encode plane's block-RNG contract, each epoch consumes
//! **exactly `shard_len − 1` raw `u64` draws**, taken as one
//! [`Xoshiro256pp::fill_u64`] block and consumed in order: swap `t` of
//! the Fisher–Yates pass maps draw `t` through Lemire's multiply-shift
//! `(r · bound) >> 64` (no rejection loop, so the draw count never
//! depends on the values drawn; the `< bound/2⁶⁴` mapping bias is
//! negligible for shard-sized bounds). A fixed draw count per epoch —
//! independent of batch size, engine, and worker count — is what lets a
//! reseeded oracle reproduce its index blocks bit-for-bit and keeps
//! stochastic runs bit-identical across engines (each node's oracle is
//! routed with the node, exactly like its RNG stream).
//!
//! Steady-state sampling allocates nothing: the permutation and raw
//! block buffers are sized at construction and reused by every reshuffle
//! ([`Xoshiro256pp::fill_u64`] reuses capacity), and
//! [`SampleOracle::next_block`] writes into a caller-owned buffer.

use crate::rng::Xoshiro256pp;

/// Seeded minibatch index generator for one node's shard. See the
/// module docs for the epoch and block-draw contracts.
#[derive(Debug, Clone)]
pub struct SampleOracle {
    shard_len: usize,
    batch: usize,
    rng: Xoshiro256pp,
    /// Current epoch's permutation of `0..shard_len`.
    perm: Vec<usize>,
    /// Reused raw-draw block (`shard_len − 1` u64s per epoch).
    block: Vec<u64>,
    /// Next unread position in `perm`.
    cursor: usize,
}

impl SampleOracle {
    /// New oracle over a shard of `shard_len` samples yielding blocks of
    /// `batch` indices (`1 ≤ batch ≤ shard_len`), seeded explicitly. The
    /// first epoch's permutation is drawn immediately.
    pub fn new(shard_len: usize, batch: usize, seed: u64) -> Self {
        assert!(shard_len > 0, "shard must be non-empty");
        assert!(
            (1..=shard_len).contains(&batch),
            "batch {batch} outside 1..={shard_len}"
        );
        let mut oracle = Self {
            shard_len,
            batch,
            rng: Xoshiro256pp::seed_from_u64(seed),
            perm: (0..shard_len).collect(),
            block: Vec::new(),
            cursor: 0,
        };
        oracle.reshuffle();
        oracle
    }

    /// Shard size.
    pub fn shard_len(&self) -> usize {
        self.shard_len
    }

    /// Block size.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Raw `u64` draws consumed per epoch (the fixed-draw contract).
    pub fn draws_per_epoch(&self) -> usize {
        self.shard_len - 1
    }

    /// Draw the next epoch permutation: one fixed-size raw block,
    /// consumed in order by a rejection-free Fisher–Yates pass.
    fn reshuffle(&mut self) {
        let m = self.shard_len;
        self.rng.fill_u64(&mut self.block, m - 1);
        for i in (1..m).rev() {
            // Draw t = m − 1 − i pairs with swap position i (consumption
            // order matches the block order).
            let r = self.block[m - 1 - i];
            let j = ((r as u128 * (i as u128 + 1)) >> 64) as usize;
            self.perm.swap(i, j);
        }
        self.cursor = 0;
    }

    /// Fill `out` with the next `batch` sample indices (clearing it
    /// first; capacity is reused). Blocks sweep per-epoch permutations
    /// and may straddle an epoch boundary.
    pub fn next_block(&mut self, out: &mut Vec<usize>) {
        out.clear();
        while out.len() < self.batch {
            if self.cursor == self.shard_len {
                self.reshuffle();
            }
            let take = (self.batch - out.len()).min(self.shard_len - self.cursor);
            out.extend_from_slice(&self.perm[self.cursor..self.cursor + take]);
            self.cursor += take;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocks_have_requested_size_and_range() {
        let mut oracle = SampleOracle::new(10, 4, 1);
        let mut out = Vec::new();
        for _ in 0..25 {
            oracle.next_block(&mut out);
            assert_eq!(out.len(), 4);
            assert!(out.iter().all(|&i| i < 10));
        }
    }

    #[test]
    fn single_sample_shard_always_yields_zero() {
        let mut oracle = SampleOracle::new(1, 1, 5);
        let mut out = Vec::new();
        for _ in 0..5 {
            oracle.next_block(&mut out);
            assert_eq!(out, vec![0]);
        }
        assert_eq!(oracle.draws_per_epoch(), 0);
    }

    #[test]
    fn same_seed_reproduces_blocks() {
        let mut a = SampleOracle::new(17, 5, 99);
        let mut b = SampleOracle::new(17, 5, 99);
        let (mut oa, mut ob) = (Vec::new(), Vec::new());
        for _ in 0..40 {
            a.next_block(&mut oa);
            b.next_block(&mut ob);
            assert_eq!(oa, ob);
        }
    }

    #[test]
    #[should_panic(expected = "outside")]
    fn oversized_batch_is_rejected() {
        let _ = SampleOracle::new(4, 5, 0);
    }
}
