//! [`DataPlane`] — per-node sample shards in one contiguous arena.
//!
//! The deterministic algorithm family evaluates closed-form objectives,
//! so it never owns data. The stochastic family (CHOCO-SGD, CEDAS)
//! trains on *sharded samples*: node `i` owns a local dataset shard and
//! draws minibatches from it. Mirroring the state plane's layout
//! discipline, all shards of one run live in a single arena:
//!
//! * `features` — one row-major `total_samples × dim` matrix,
//! * `labels` — one `total_samples` vector,
//! * `off` — CSR-style per-node prefix sums (`n + 1` entries), so node
//!   `i`'s shard is the contiguous row range `off[i]..off[i+1]`.
//!
//! Synthesis is deterministic: node `i`'s samples are drawn from the
//! run driver's per-node stream derivation (`seed ⊕ golden·(i+1)`,
//! SplitMix-expanded) applied to a *data-domain-salted* seed — so a
//! data plane is a pure function of
//! `(n, samples_per_node, dim, noise_sd, seed)`, identical across
//! engines, worker counts, and machines, while never aligning a node's
//! runtime RNG stream with the stream that synthesized its shard.

use crate::linalg::vecops;
use crate::rng::{Normal, Xoshiro256pp};

/// The per-node stream salt shared with the run driver's node RNG
/// derivation (decorrelated streams, stable across engines).
const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// Domain salt separating data-synthesis streams from the run driver's
/// node RNG streams. Without it, passing the same seed as both the data
/// seed and the run seed would hand every node a runtime stream that
/// starts at the exact state that synthesized its own shard —
/// correlating compression/sampling noise with the dataset.
const DATA_DOMAIN: u64 = 0xDA7A_0BEC_5EED_0001;

/// All sample shards of one run in a single contiguous arena. See the
/// module docs for the layout.
#[derive(Debug, Clone)]
pub struct DataPlane {
    n: usize,
    dim: usize,
    /// Row-major `total_samples × dim` feature matrix.
    features: Vec<f64>,
    /// One label per sample (`±1` for classification, real-valued for
    /// regression).
    labels: Vec<f64>,
    /// Per-node shard offsets (`n + 1` prefix sums).
    off: Vec<usize>,
}

impl DataPlane {
    /// Assemble a plane from raw parts (tests / external loaders).
    /// `off` must be `n + 1` non-decreasing prefix sums ending at the
    /// sample count, and every shard must be non-empty.
    pub fn from_parts(dim: usize, features: Vec<f64>, labels: Vec<f64>, off: Vec<usize>) -> Self {
        assert!(dim > 0, "feature dimension must be positive");
        assert!(off.len() >= 2, "need at least one node");
        assert_eq!(off[0], 0, "offsets must start at 0");
        assert!(off.windows(2).all(|w| w[0] < w[1]), "every shard must be non-empty");
        let total = *off.last().unwrap();
        assert_eq!(labels.len(), total, "one label per sample");
        assert_eq!(features.len(), total * dim, "features must be total × dim");
        Self { n: off.len() - 1, dim, features, labels, off }
    }

    /// Synthesize a sharded binary-classification problem: a true weight
    /// `w* ~ N(0, I)` is drawn from the master stream, then node `i`'s
    /// shard comes from the per-node stream
    /// `(seed ⊕ data-salt) ⊕ golden·(i+1)`: features `~ N(0, I)`,
    /// labels `sign(w*·x + ε)`, `ε ~ N(0, noise_sd²)`. Returns
    /// `(plane, w*)`.
    pub fn synthetic_logistic(
        n: usize,
        samples_per_node: usize,
        dim: usize,
        noise_sd: f64,
        seed: u64,
    ) -> (Self, Vec<f64>) {
        Self::synthesize(n, samples_per_node, dim, noise_sd, seed, true)
    }

    /// Synthesize a sharded least-squares problem: like
    /// [`Self::synthetic_logistic`] but with real-valued labels
    /// `y = w*·x + ε`. Returns `(plane, w*)`.
    pub fn synthetic_least_squares(
        n: usize,
        samples_per_node: usize,
        dim: usize,
        noise_sd: f64,
        seed: u64,
    ) -> (Self, Vec<f64>) {
        Self::synthesize(n, samples_per_node, dim, noise_sd, seed, false)
    }

    fn synthesize(
        n: usize,
        samples_per_node: usize,
        dim: usize,
        noise_sd: f64,
        seed: u64,
        classify: bool,
    ) -> (Self, Vec<f64>) {
        assert!(n > 0 && samples_per_node > 0 && dim > 0, "plane must be non-empty");
        assert!(noise_sd >= 0.0, "noise must be non-negative");
        let std = Normal::new(0.0, 1.0);
        let noise = Normal::new(0.0, noise_sd);
        // Salt the seed into the data domain so sharing one seed between
        // the data plane and the run config never aligns a node's
        // runtime stream with its synthesis stream.
        let salted = seed ^ DATA_DOMAIN;
        let mut master = Xoshiro256pp::seed_from_u64(salted);
        let w_star = std.sample_vec(&mut master, dim);
        let total = n * samples_per_node;
        let mut features = Vec::with_capacity(total * dim);
        let mut labels = Vec::with_capacity(total);
        let mut off = Vec::with_capacity(n + 1);
        off.push(0);
        for i in 0..n {
            let mut rng =
                Xoshiro256pp::seed_from_u64(salted ^ GOLDEN.wrapping_mul(i as u64 + 1));
            for _ in 0..samples_per_node {
                let start = features.len();
                for _ in 0..dim {
                    features.push(std.sample(&mut rng));
                }
                let margin =
                    vecops::dot(&w_star, &features[start..]) + noise.sample(&mut rng);
                labels.push(if classify {
                    if margin >= 0.0 {
                        1.0
                    } else {
                        -1.0
                    }
                } else {
                    margin
                });
            }
            off.push(labels.len());
        }
        (Self { n, dim, features, labels, off }, w_star)
    }

    /// Node count.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Feature dimension.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Samples across all shards.
    pub fn total_samples(&self) -> usize {
        *self.off.last().unwrap()
    }

    /// Node `i`'s shard size.
    #[inline]
    pub fn shard_len(&self, i: usize) -> usize {
        self.off[i + 1] - self.off[i]
    }

    /// Feature row of node `i`'s local sample `j`.
    #[inline]
    pub fn feature_row(&self, i: usize, j: usize) -> &[f64] {
        debug_assert!(j < self.shard_len(i), "sample index out of shard");
        vecops::row(&self.features, self.dim, self.off[i] + j)
    }

    /// Label of node `i`'s local sample `j`.
    #[inline]
    pub fn label(&self, i: usize, j: usize) -> f64 {
        debug_assert!(j < self.shard_len(i), "sample index out of shard");
        self.labels[self.off[i] + j]
    }

    /// Global classification accuracy of weights `w` over **all** shards
    /// (sign agreement; meaningful for the `±1`-labeled classification
    /// planes).
    pub fn accuracy(&self, w: &[f64]) -> f64 {
        assert_eq!(w.len(), self.dim, "weight dimension mismatch");
        let total = self.total_samples();
        let hits = (0..total)
            .filter(|&s| {
                let row = vecops::row(&self.features, self.dim, s);
                vecops::dot(w, row) * self.labels[s] > 0.0
            })
            .count();
        hits as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthesis_is_deterministic_and_shaped() {
        let (a, wa) = DataPlane::synthetic_logistic(3, 5, 4, 0.1, 42);
        let (b, wb) = DataPlane::synthetic_logistic(3, 5, 4, 0.1, 42);
        assert_eq!(wa, wb);
        assert_eq!(a.n(), 3);
        assert_eq!(a.dim(), 4);
        assert_eq!(a.total_samples(), 15);
        for i in 0..3 {
            assert_eq!(a.shard_len(i), 5);
            for j in 0..5 {
                assert_eq!(a.feature_row(i, j), b.feature_row(i, j));
                assert_eq!(a.label(i, j), b.label(i, j));
                assert!(a.label(i, j) == 1.0 || a.label(i, j) == -1.0);
            }
        }
        let (c, _) = DataPlane::synthetic_logistic(3, 5, 4, 0.1, 43);
        assert_ne!(a.feature_row(0, 0), c.feature_row(0, 0), "seed must matter");
    }

    #[test]
    fn true_weights_score_high_at_low_noise() {
        let (plane, w_star) = DataPlane::synthetic_logistic(4, 64, 6, 0.01, 7);
        assert!(plane.accuracy(&w_star) > 0.95, "acc = {}", plane.accuracy(&w_star));
        // The zero vector classifies nothing correctly (no positive margin).
        assert_eq!(plane.accuracy(&vec![0.0; 6]), 0.0);
    }

    #[test]
    fn least_squares_labels_are_real_valued() {
        let (plane, w_star) = DataPlane::synthetic_least_squares(2, 8, 3, 0.0, 9);
        for j in 0..8 {
            let row = plane.feature_row(1, j);
            let y = plane.label(1, j);
            assert!((vecops::dot(&w_star, row) - y).abs() < 1e-12, "noise-free labels");
        }
    }

    #[test]
    fn from_parts_validates() {
        let p = DataPlane::from_parts(2, vec![1.0, 2.0, 3.0, 4.0], vec![1.0, -1.0], vec![0, 1, 2]);
        assert_eq!(p.n(), 2);
        assert_eq!(p.feature_row(1, 0), &[3.0, 4.0]);
        let bad = std::panic::catch_unwind(|| {
            DataPlane::from_parts(2, vec![1.0, 2.0], vec![1.0], vec![0, 1, 1])
        });
        assert!(bad.is_err(), "empty shard must be rejected");
    }
}
