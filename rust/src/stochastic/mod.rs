//! The stochastic data plane: sharded sample arenas, seeded minibatch
//! oracles, and the minibatch objective layer.
//!
//! The deterministic algorithm family (DGD, DGD^t, naive compressed,
//! ADC-DGD, QDGD) runs full gradients of closed-form objectives. The
//! strongest compressed-consensus baselines from the related literature
//! — CHOCO-SGD (Koloskova et al., arXiv:1902.00340 / 1907.09356) and
//! CEDAS (Huang & Pu, arXiv:2301.05872) — are *stochastic*: each node
//! owns a data shard and steps on minibatch gradients. This module is
//! the plane that makes those workloads first-class, following the same
//! arena discipline as the state, mailbox, and encode planes:
//!
//! * [`DataPlane`] — every node's sample shard in one contiguous
//!   row-major arena with CSR-style per-node offsets, synthesized
//!   deterministically from the run driver's per-node stream derivation.
//! * [`SampleOracle`] — per-node seeded minibatch index blocks: each
//!   epoch is a random permutation of the shard drawn as **one
//!   fixed-size raw `u64` block** (exactly `shard_len − 1` draws,
//!   consumed in order through a rejection-free Fisher–Yates pass) — the
//!   stochastic analogue of the encode plane's block-RNG contract, so
//!   oracle draws are reproducible bit-for-bit and independent of
//!   engine or worker count.
//! * [`StochasticObjective`] / [`ShardObjective`] — the minibatch layer
//!   over [`crate::objective`]: logistic classification and quadratic
//!   least-squares over a shard, with `minibatch_grad_into` writing
//!   straight into [`crate::state::NodeRows`] rows (zero allocation on
//!   the sample → gradient path). Algorithms discover the surface
//!   through [`crate::objective::Objective::as_stochastic`] and fall
//!   back to full gradients on deterministic objectives.
//!
//! The algorithms riding on this plane live in [`crate::algorithms`]
//! ([`crate::algorithms::ChocoSgdNode`], [`crate::algorithms::CedasNode`]);
//! the `ADCDGD_BENCH_ONLY=stochastic` hotpath section asserts that
//! steady-state sample → encode → consume rounds allocate nothing.

mod data;
mod objective;
mod oracle;

pub use data::DataPlane;
pub use objective::{ShardLoss, ShardObjective, StochasticObjective, StochasticObjectiveRef};
pub use oracle::SampleOracle;
