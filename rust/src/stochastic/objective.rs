//! [`StochasticObjective`] — the minibatch layer over [`crate::objective`].
//!
//! A stochastic objective is a plain [`Objective`] (full-shard value and
//! gradient, used by the metric pipeline and by full-batch algorithm
//! runs) that can additionally evaluate a *minibatch* gradient over an
//! explicit index block, writing straight into a caller-provided row
//! (typically a [`crate::state::NodeRows`] row — no allocation on the
//! sample → gradient path).
//!
//! [`ShardObjective`] is the concrete family: logistic classification
//! and quadratic least-squares losses over one node's shard of a shared
//! [`DataPlane`]. Algorithms discover the minibatch surface through
//! [`Objective::as_stochastic`], so the registry, scenario, and engine
//! layers keep passing plain `ObjectiveRef`s — a stochastic algorithm
//! handed a deterministic objective simply falls back to full
//! gradients.

use super::DataPlane;
use crate::linalg::vecops;
use crate::objective::Objective;
use std::sync::Arc;

/// An objective that can evaluate minibatch gradients over explicit
/// sample-index blocks (drawn by a [`super::SampleOracle`]).
pub trait StochasticObjective: Objective {
    /// Samples in this node's shard.
    fn num_samples(&self) -> usize;

    /// Minibatch gradient `∇F(x; B) = (1/|B|) Σ_{j∈B} ∇ℓ_j(x) + λx`
    /// written into `out` (length `dim`). `idx` holds local shard
    /// indices; duplicates are averaged like any other sample. Allocates
    /// nothing.
    fn minibatch_grad_into(&self, x: &[f64], idx: &[usize], out: &mut [f64]);
}

/// Shared handle to a stochastic objective.
pub type StochasticObjectiveRef = Arc<dyn StochasticObjective>;

/// Which per-sample loss a [`ShardObjective`] applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardLoss {
    /// `ℓ_j(w) = log(1 + exp(−y_j · w·x_j))`, labels `±1`.
    Logistic,
    /// `ℓ_j(w) = ½ (w·x_j − y_j)²`.
    LeastSquares,
}

/// One node's loss over its [`DataPlane`] shard:
/// `f_i(w) = (1/m_i) Σ_j ℓ_j(w) + (λ/2)‖w‖²`.
#[derive(Debug, Clone)]
pub struct ShardObjective {
    data: Arc<DataPlane>,
    node: usize,
    loss: ShardLoss,
    lambda: f64,
}

impl ShardObjective {
    /// Logistic-classification loss over node `node`'s shard.
    pub fn logistic(data: Arc<DataPlane>, node: usize, lambda: f64) -> Self {
        Self::new(data, node, ShardLoss::Logistic, lambda)
    }

    /// Least-squares loss over node `node`'s shard.
    pub fn least_squares(data: Arc<DataPlane>, node: usize, lambda: f64) -> Self {
        Self::new(data, node, ShardLoss::LeastSquares, lambda)
    }

    /// Generic constructor.
    pub fn new(data: Arc<DataPlane>, node: usize, loss: ShardLoss, lambda: f64) -> Self {
        assert!(node < data.n(), "node {node} outside the data plane");
        assert!(lambda >= 0.0, "regularization must be non-negative");
        Self { data, node, loss, lambda }
    }

    /// The backing data plane.
    pub fn data(&self) -> &Arc<DataPlane> {
        &self.data
    }

    /// Per-sample gradient coefficient: `∇ℓ_j(w) = coef · x_j`, already
    /// divided by the batch size `inv_m`-style factor.
    #[inline]
    fn sample_coef(&self, x: &[f64], row: &[f64], y: f64, inv_m: f64) -> f64 {
        match self.loss {
            ShardLoss::Logistic => {
                let margin = y * vecops::dot(x, row);
                // σ(−margin) computed stably on both signs.
                let s = if margin > 0.0 {
                    let e = (-margin).exp();
                    e / (1.0 + e)
                } else {
                    1.0 / (1.0 + margin.exp())
                };
                -y * s * inv_m
            }
            ShardLoss::LeastSquares => (vecops::dot(x, row) - y) * inv_m,
        }
    }

    /// Per-sample loss value.
    #[inline]
    fn sample_loss(&self, x: &[f64], row: &[f64], y: f64) -> f64 {
        match self.loss {
            ShardLoss::Logistic => {
                let margin = y * vecops::dot(x, row);
                // log(1 + e^{−margin}) computed stably.
                if margin > 0.0 {
                    (-margin).exp().ln_1p()
                } else {
                    -margin + margin.exp().ln_1p()
                }
            }
            ShardLoss::LeastSquares => {
                let r = vecops::dot(x, row) - y;
                0.5 * r * r
            }
        }
    }
}

impl Objective for ShardObjective {
    fn dim(&self) -> usize {
        self.data.dim()
    }

    fn value(&self, x: &[f64]) -> f64 {
        let m = self.data.shard_len(self.node);
        let mut loss = 0.0;
        for j in 0..m {
            let row = self.data.feature_row(self.node, j);
            loss += self.sample_loss(x, row, self.data.label(self.node, j));
        }
        loss / m as f64 + 0.5 * self.lambda * vecops::norm2_sq(x)
    }

    fn grad_into(&self, x: &[f64], out: &mut [f64]) {
        let m = self.data.shard_len(self.node);
        let inv_m = 1.0 / m as f64;
        for (o, &wi) in out.iter_mut().zip(x.iter()) {
            *o = self.lambda * wi;
        }
        for j in 0..m {
            let row = self.data.feature_row(self.node, j);
            let coef = self.sample_coef(x, row, self.data.label(self.node, j), inv_m);
            vecops::axpy(coef, row, out);
        }
    }

    fn lipschitz(&self) -> Option<f64> {
        let m = self.data.shard_len(self.node);
        let s: f64 = (0..m)
            .map(|j| vecops::norm2_sq(self.data.feature_row(self.node, j)))
            .sum();
        Some(match self.loss {
            ShardLoss::Logistic => s / (4.0 * m as f64) + self.lambda,
            ShardLoss::LeastSquares => s / m as f64 + self.lambda,
        })
    }

    fn as_stochastic(&self) -> Option<&dyn StochasticObjective> {
        Some(self)
    }
}

impl StochasticObjective for ShardObjective {
    fn num_samples(&self) -> usize {
        self.data.shard_len(self.node)
    }

    fn minibatch_grad_into(&self, x: &[f64], idx: &[usize], out: &mut [f64]) {
        assert!(!idx.is_empty(), "minibatch must be non-empty");
        let inv_m = 1.0 / idx.len() as f64;
        for (o, &wi) in out.iter_mut().zip(x.iter()) {
            *o = self.lambda * wi;
        }
        for &j in idx {
            let row = self.data.feature_row(self.node, j);
            let coef = self.sample_coef(x, row, self.data.label(self.node, j), inv_m);
            vecops::axpy(coef, row, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::check_gradient;

    fn plane() -> Arc<DataPlane> {
        Arc::new(DataPlane::synthetic_logistic(3, 12, 4, 0.1, 11).0)
    }

    #[test]
    fn full_gradients_pass_the_numeric_check() {
        let data = plane();
        for node in 0..3 {
            let log = ShardObjective::logistic(Arc::clone(&data), node, 0.01);
            check_gradient(&log, &[0.2, -0.4, 0.1, 0.3], 1e-6, 1e-5).unwrap();
        }
        let (reg_data, _) = DataPlane::synthetic_least_squares(2, 10, 3, 0.2, 13);
        let ls = ShardObjective::least_squares(Arc::new(reg_data), 1, 0.05);
        check_gradient(&ls, &[0.5, -0.1, 0.2], 1e-6, 1e-5).unwrap();
    }

    #[test]
    fn in_order_full_minibatch_is_bitwise_the_full_gradient() {
        // The full-batch fast path of the stochastic algorithms relies on
        // this: a minibatch over the identity index block performs the
        // exact accumulation sequence of `grad_into`.
        let data = plane();
        let obj = ShardObjective::logistic(Arc::clone(&data), 1, 0.001);
        let x = [0.3, -0.2, 0.7, 0.05];
        let idx: Vec<usize> = (0..obj.num_samples()).collect();
        let (mut full, mut mini) = (vec![0.0; 4], vec![0.0; 4]);
        obj.grad_into(&x, &mut full);
        obj.minibatch_grad_into(&x, &idx, &mut mini);
        for (a, b) in full.iter().zip(mini.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn minibatch_matches_manual_average() {
        let data = plane();
        let obj = ShardObjective::logistic(Arc::clone(&data), 0, 0.0);
        let x = [0.1, 0.2, -0.3, 0.4];
        let idx = [3usize, 7, 3];
        let mut g = vec![0.0; 4];
        obj.minibatch_grad_into(&x, &idx, &mut g);
        // Manual: average of the per-sample gradients (duplicates count).
        let mut expect = vec![0.0; 4];
        for &j in &idx {
            let row = data.feature_row(0, j);
            let y = data.label(0, j);
            let margin = y * vecops::dot(&x, row);
            let s = 1.0 / (1.0 + margin.exp());
            vecops::axpy(-y * s / 3.0, row, &mut expect);
        }
        for (a, b) in g.iter().zip(expect.iter()) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn stochastic_surface_is_discoverable_through_objective() {
        let data = plane();
        let obj: crate::algorithms::ObjectiveRef =
            Arc::new(ShardObjective::logistic(data, 2, 0.01));
        let sto = obj.as_stochastic().expect("shard objective is stochastic");
        assert_eq!(sto.num_samples(), 12);
        // Plain objectives stay non-stochastic.
        let plain: crate::algorithms::ObjectiveRef =
            Arc::new(crate::objective::ScalarQuadratic::new(1.0, 0.0));
        assert!(plain.as_stochastic().is_none());
    }
}
