//! Deterministic pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate, so this module
//! implements the generators the library needs from scratch:
//!
//! * [`SplitMix64`] — a tiny 64-bit mixer used for seeding.
//! * [`Xoshiro256pp`] — the main generator (xoshiro256++ by Blackman &
//!   Vigna), fast, 256-bit state, passes BigCrush.
//!
//! All stochastic components of the library (compression operators, random
//! topologies, random objectives, trial repetition) draw from explicitly
//! seeded generators, so every experiment is exactly reproducible. Per-node
//! streams are decorrelated with [`Xoshiro256pp::fork`], which mixes the
//! parent state through SplitMix64 rather than sharing a sequence.

mod distributions;

pub use distributions::{Bernoulli, Normal, Uniform};

/// Map one raw 64-bit draw to the uniform `[0, 1)` value
/// [`Xoshiro256pp::next_f64`] would have produced from it (53-bit
/// resolution). This is the **block-draw ordering contract** the encode
/// plane relies on: `next_f64() ≡ block_f64(next_u64())` bit-for-bit, so
/// a kernel that block-fills a `u64` buffer with [`Xoshiro256pp::fill_u64`]
/// and converts lazily consumes the *identical* `next_f64` sequence as
/// the scalar path — golden bit patterns are preserved.
#[inline(always)]
pub fn block_f64(bits: u64) -> f64 {
    (bits >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// SplitMix64: a 64-bit state mixer. Primarily used to expand a single
/// `u64` seed into the 256-bit state of [`Xoshiro256pp`].
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new mixer from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 — the library's workhorse generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

#[inline(always)]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 (never yields the all-zero state).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Next 32 random bits (upper half of `next_u64`).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform `f64` in `[0, 1)` with 53-bit resolution. Defined as
    /// `block_f64(next_u64())` so block-filled draws ([`Self::fill_u64`] +
    /// [`block_f64`]) are bit-identical to scalar draws.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        block_f64(self.next_u64())
    }

    /// Refill `buf` with exactly `n` raw 64-bit draws (clearing previous
    /// contents, reusing capacity). Advances the generator state exactly
    /// as `n` calls of [`Self::next_u64`] would — the encode plane's
    /// quantization kernels draw one block per message and convert each
    /// element with [`block_f64`] in consumption order, which preserves
    /// the scalar `next_f64` sequence bit-for-bit.
    pub fn fill_u64(&mut self, buf: &mut Vec<u64>, n: usize) {
        buf.clear();
        buf.reserve(n);
        for _ in 0..n {
            buf.push(self.next_u64());
        }
    }

    /// Uniform `f32` in `[0, 1)` with 24-bit resolution.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` (Lemire's rejection method).
    pub fn next_bounded(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        // Rejection sampling on the high bits to avoid modulo bias.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Derive an independent child generator. The child's state is the
    /// SplitMix64 expansion of a fresh draw, so parent and child sequences
    /// are decorrelated.
    pub fn fork(&mut self) -> Self {
        Self::seed_from_u64(self.next_u64())
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        if n < 2 {
            return;
        }
        for i in (1..n).rev() {
            let j = self.next_bounded((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_bounded((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_streams() {
        let mut a = Xoshiro256pp::seed_from_u64(42);
        let mut b = Xoshiro256pp::seed_from_u64(42);
        let mut c = Xoshiro256pp::seed_from_u64(43);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn block_draws_match_scalar_draws_bitwise() {
        // The encode-plane contract: fill_u64 + block_f64 must reproduce
        // the exact next_f64 sequence (values and state advancement).
        let mut scalar = Xoshiro256pp::seed_from_u64(99);
        let mut blocked = Xoshiro256pp::seed_from_u64(99);
        let mut buf = Vec::new();
        for block_len in [1usize, 7, 64, 3] {
            blocked.fill_u64(&mut buf, block_len);
            assert_eq!(buf.len(), block_len);
            for &bits in &buf {
                assert_eq!(block_f64(bits).to_bits(), scalar.next_f64().to_bits());
            }
        }
        // Both generators end in the same state.
        assert_eq!(scalar.next_u64(), blocked.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        for _ in 0..10_000 {
            let u = rng.next_f64();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn bounded_is_unbiased_enough() {
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[rng.next_bounded(7) as usize] += 1;
        }
        for &c in &counts {
            let expect = n / 7;
            assert!(
                (c as i64 - expect as i64).abs() < (expect as i64) / 10,
                "counts={counts:?}"
            );
        }
    }

    #[test]
    fn fork_decorrelates() {
        let mut parent = Xoshiro256pp::seed_from_u64(5);
        let mut child = parent.fork();
        let p: Vec<u64> = (0..4).map(|_| parent.next_u64()).collect();
        let c: Vec<u64> = (0..4).map(|_| child.next_u64()).collect();
        assert_ne!(p, c);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let idx = rng.sample_indices(50, 10);
        assert_eq!(idx.len(), 10);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
        assert!(idx.iter().all(|&i| i < 50));
    }
}
