//! Sampling distributions on top of [`Xoshiro256pp`](super::Xoshiro256pp).

use super::Xoshiro256pp;

/// Uniform distribution on `[lo, hi)`.
#[derive(Debug, Clone, Copy)]
pub struct Uniform {
    lo: f64,
    hi: f64,
}

impl Uniform {
    /// New uniform distribution; requires `lo < hi`.
    pub fn new(lo: f64, hi: f64) -> Self {
        assert!(lo < hi, "Uniform requires lo < hi ({lo} >= {hi})");
        Self { lo, hi }
    }

    /// Draw one sample.
    #[inline]
    pub fn sample(&self, rng: &mut Xoshiro256pp) -> f64 {
        self.lo + (self.hi - self.lo) * rng.next_f64()
    }

    /// Fill a vector with samples.
    pub fn sample_vec(&self, rng: &mut Xoshiro256pp, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.sample(rng)).collect()
    }
}

/// Normal distribution via the Marsaglia polar method.
#[derive(Debug, Clone, Copy)]
pub struct Normal {
    mean: f64,
    sd: f64,
}

impl Normal {
    /// New normal distribution; requires `sd >= 0`.
    pub fn new(mean: f64, sd: f64) -> Self {
        assert!(sd >= 0.0, "Normal requires sd >= 0");
        Self { mean, sd }
    }

    /// Draw one sample.
    pub fn sample(&self, rng: &mut Xoshiro256pp) -> f64 {
        // Marsaglia polar: rejection from the unit disc, no trig calls.
        loop {
            let u = 2.0 * rng.next_f64() - 1.0;
            let v = 2.0 * rng.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let scale = (-2.0 * s.ln() / s).sqrt();
                return self.mean + self.sd * u * scale;
            }
        }
    }

    /// Fill a vector with samples.
    pub fn sample_vec(&self, rng: &mut Xoshiro256pp, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.sample(rng)).collect()
    }
}

/// Bernoulli distribution with success probability `p`.
#[derive(Debug, Clone, Copy)]
pub struct Bernoulli {
    p: f64,
}

impl Bernoulli {
    /// New Bernoulli distribution; requires `p` in `[0, 1]`.
    pub fn new(p: f64) -> Self {
        assert!((0.0..=1.0).contains(&p), "Bernoulli requires p in [0,1]");
        Self { p }
    }

    /// Draw one sample.
    #[inline]
    pub fn sample(&self, rng: &mut Xoshiro256pp) -> bool {
        rng.next_f64() < self.p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_range_and_mean() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let d = Uniform::new(0.0, 10.0);
        let xs = d.sample_vec(&mut rng, 100_000);
        assert!(xs.iter().all(|&x| (0.0..10.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 5.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let d = Normal::new(3.0, 2.0);
        let xs = d.sample_vec(&mut rng, 200_000);
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn bernoulli_frequency() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let d = Bernoulli::new(0.3);
        let n = 100_000;
        let hits = (0..n).filter(|_| d.sample(&mut rng)).count();
        let freq = hits as f64 / n as f64;
        assert!((freq - 0.3).abs() < 0.01, "freq={freq}");
    }

    #[test]
    #[should_panic]
    fn uniform_rejects_bad_bounds() {
        let _ = Uniform::new(1.0, 1.0);
    }
}
