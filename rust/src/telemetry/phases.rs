//! Span-style wall-clock phase timers for the engine round loop.
//!
//! A [`PhaseTimers`] is a fixed array of `(nanos, count)` accumulator
//! pairs plus a phase-name table bound by whichever engine runs — so
//! one timer instance survives engine fallback (dim → pool) and epoch
//! segmentation without reallocation. Recording a span is two
//! [`Instant`] reads and two `Cell` stores; nothing on the path
//! allocates, which is what lets the `ADCDGD_BENCH_ONLY=telemetry`
//! hotpath section assert zero steady-state allocations with full
//! instrumentation enabled.
//!
//! **Timing is observational.** Phase wall time never feeds the
//! simulated clock ([`crate::network::Bus::sim_clock`]), the RNG
//! streams, or any quantity on a golden trajectory — the bit-identity
//! suites pass with telemetry on or off, which
//! `rust/tests/engine_equivalence.rs` pins.
//!
//! Concurrency contract: like [`super::Registry`], timers are written
//! only by the engine's calling/coordinator thread. In the parallel
//! engines the observable phases are therefore the *coordinator's*
//! barrier-to-barrier (threaded/pool) or gate-to-gate (dim) segments;
//! worker-interior time shows up inside the segment that contains it.
//!
//! Phase-name tables (schema v1):
//!
//! | Engine | Phases |
//! |---|---|
//! | sequential | `compress`, `broadcast`, `deliver`, `consume`, `reclaim`, `observe` |
//! | threaded / pool | `send`, `deliver_consume`, `observe` |
//! | dim | `a_diff_norm`, `b_stage`, `c_encode`, `d_broadcast`, `d2_collect`, `e1_mirror`, `e2_mix_grad`, `observe` |
//!
//! For sequential, `compress` is [`NodeLogic::make_message`] (quantize +
//! stage into the payload pool) and `broadcast` is the bus fan-out
//! including wire serialization when `measure_wire` is on; `consume`
//! contains decode + mix + grad (they execute inside
//! [`NodeLogic::consume`], invisible to the engine). For threaded/pool,
//! `send` spans worker emit (compress + serialize + broadcast),
//! `deliver_consume` the advance/deliver plus worker consume (decode +
//! mix + grad), `observe` the snapshot + observer callback. The dim
//! table names the engine's seven A–E2 pipeline phases directly.
//!
//! [`NodeLogic::make_message`]: crate::algorithms::NodeLogic::make_message
//! [`NodeLogic::consume`]: crate::algorithms::NodeLogic::consume

use std::cell::Cell;
use std::time::Instant;

/// Most phases any engine declares (dim's 7 + observe).
pub const MAX_PHASES: usize = 16;

/// Sequential engine phase names (see module docs).
pub const SEQUENTIAL_PHASES: &[&str] =
    &["compress", "broadcast", "deliver", "consume", "reclaim", "observe"];

/// Threaded/pool coordinator barrier-segment names (see module docs).
pub const WORKER_PHASES: &[&str] = &["send", "deliver_consume", "observe"];

/// Dim engine gate-to-gate phase names (the seven A–E2 pipeline phases
/// plus the coordinator's snapshot/observe segment).
pub const DIM_PHASES: &[&str] = &[
    "a_diff_norm",
    "b_stage",
    "c_encode",
    "d_broadcast",
    "d2_collect",
    "e1_mirror",
    "e2_mix_grad",
    "observe",
];

/// Fixed-capacity per-phase wall-time accumulators (see module docs).
pub struct PhaseTimers {
    /// Bound by the engine at segment start ([`PhaseTimers::bind`]);
    /// empty until then.
    names: Cell<&'static [&'static str]>,
    nanos: [Cell<u64>; MAX_PHASES],
    counts: [Cell<u64>; MAX_PHASES],
}

impl Default for PhaseTimers {
    fn default() -> Self {
        Self::new()
    }
}

impl PhaseTimers {
    /// Fresh timers with no phase table bound yet.
    pub fn new() -> Self {
        Self {
            names: Cell::new(&[]),
            nanos: std::array::from_fn(|_| Cell::new(0)),
            counts: std::array::from_fn(|_| Cell::new(0)),
        }
    }

    /// Bind the phase-name table. Idempotent per run: the engine calls
    /// this at every segment start, so the driver does not need to know
    /// which engine (or dim-fallback) will actually execute. Rebinding
    /// to a *different* table mid-run would mix meanings, so it panics.
    pub fn bind(&self, names: &'static [&'static str]) {
        assert!(names.len() <= MAX_PHASES, "telemetry: too many phases");
        let cur = self.names.get();
        assert!(
            cur.is_empty() || std::ptr::eq(cur, names),
            "telemetry: phase table rebound mid-run"
        );
        self.names.set(names);
    }

    /// The bound phase-name table (empty before any engine ran).
    pub fn names(&self) -> &'static [&'static str] {
        self.names.get()
    }

    /// Start a span: one monotonic clock read.
    #[inline]
    pub fn start(&self) -> Instant {
        Instant::now()
    }

    /// Close a span over `phase`, returning the close instant so
    /// back-to-back phases chain with a single clock read between them:
    /// `t = timers.lap(PH_A, t); ...; t = timers.lap(PH_B, t);`
    #[inline]
    pub fn lap(&self, phase: usize, t0: Instant) -> Instant {
        let t1 = Instant::now();
        let ns = &self.nanos[phase];
        ns.set(ns.get() + t1.duration_since(t0).as_nanos() as u64);
        let c = &self.counts[phase];
        c.set(c.get() + 1);
        t1
    }

    /// Accumulated nanoseconds in `phase`.
    pub fn phase_nanos(&self, phase: usize) -> u64 {
        self.nanos[phase].get()
    }

    /// Spans recorded in `phase`.
    pub fn phase_count(&self, phase: usize) -> u64 {
        self.counts[phase].get()
    }

    /// Total accumulated nanoseconds across all bound phases.
    pub fn total_nanos(&self) -> u64 {
        (0..self.names.get().len()).map(|i| self.nanos[i].get()).sum()
    }

    /// Snapshot as `(name, seconds, count)` rows in table order.
    /// Allocates — harvest-time only.
    pub fn snapshot(&self) -> Vec<(&'static str, f64, u64)> {
        self.names
            .get()
            .iter()
            .enumerate()
            .map(|(i, name)| (*name, self.nanos[i].get() as f64 * 1e-9, self.counts[i].get()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate_per_phase() {
        let t = PhaseTimers::new();
        t.bind(WORKER_PHASES);
        let mut now = t.start();
        now = t.lap(0, now);
        now = t.lap(1, now);
        let _ = t.lap(0, now);
        assert_eq!(t.phase_count(0), 2);
        assert_eq!(t.phase_count(1), 1);
        assert_eq!(t.phase_count(2), 0);
        let snap = t.snapshot();
        assert_eq!(snap.len(), WORKER_PHASES.len());
        assert_eq!(snap[0].0, "send");
        assert_eq!(snap[0].2, 2);
        assert_eq!(
            t.total_nanos(),
            t.phase_nanos(0) + t.phase_nanos(1) + t.phase_nanos(2)
        );
    }

    #[test]
    fn rebind_same_table_is_idempotent() {
        let t = PhaseTimers::new();
        t.bind(DIM_PHASES);
        t.bind(DIM_PHASES); // every epoch segment rebinds
        assert_eq!(t.names().len(), 8);
    }

    #[test]
    #[should_panic(expected = "rebound mid-run")]
    fn rebind_different_table_rejected() {
        let t = PhaseTimers::new();
        t.bind(DIM_PHASES);
        t.bind(SEQUENTIAL_PHASES);
    }
}
