//! Run-trace export: one JSON line per recorded round (schema v1).
//!
//! The trace is a JSON-Lines file built on [`crate::util::json`]:
//!
//! * **Line 1 — meta object.** `schema: "adcdgd-trace"`, `version: 1`,
//!   the per-round column list, the engine's phase table with
//!   accumulated wall seconds, and the run's counter summary.
//! * **Lines 2.. — round records.** One object per *recorded* round
//!   (the `record_every` cadence), mirroring
//!   [`crate::metrics::RunMetrics`] column for column — so the trace's
//!   cumulative byte columns equal `RunOutput.metrics` exactly, by
//!   construction, and `scripts/check_trace_schema.py` can validate a
//!   file without knowing anything about the scenario.
//!
//! The writer is buffered ([`std::io::BufWriter`]) and runs **after**
//! the engine finished — tracing never touches the round hot path.

use std::io::{self, BufWriter, Write};
use std::path::Path;

use super::TelemetrySummary;
use crate::metrics::RunMetrics;
use crate::util::json::Json;

/// Version stamped into every trace meta line; bump on any column or
/// meta-shape change.
pub const TRACE_SCHEMA_VERSION: u64 = 1;

/// Per-round column names, in [`RunMetrics`] order.
pub const TRACE_COLUMNS: &[&str] = &[
    "round",
    "grad_iterations",
    "objective",
    "grad_norm",
    "consensus_error",
    "bytes_cumulative",
    "measured_bytes_cumulative",
    "max_transmitted",
    "saturations",
];

/// Meta (first) line of a trace as a [`Json`] value.
pub fn trace_meta_json(metrics: &RunMetrics, summary: &TelemetrySummary) -> Json {
    let mut obj = std::collections::BTreeMap::new();
    obj.insert("schema".to_string(), Json::Str("adcdgd-trace".to_string()));
    obj.insert("version".to_string(), Json::Num(TRACE_SCHEMA_VERSION as f64));
    obj.insert("rows".to_string(), Json::Num(metrics.len() as f64));
    obj.insert(
        "columns".to_string(),
        Json::Arr(TRACE_COLUMNS.iter().map(|c| Json::Str(c.to_string())).collect()),
    );
    obj.insert(
        "phases".to_string(),
        Json::Arr(
            summary
                .phases
                .iter()
                .map(|p| {
                    let mut ph = std::collections::BTreeMap::new();
                    ph.insert("name".to_string(), Json::Str(p.name.to_string()));
                    ph.insert("total_secs".to_string(), Json::Num(p.total_secs));
                    ph.insert("count".to_string(), Json::Num(p.count as f64));
                    Json::Obj(ph)
                })
                .collect(),
        ),
    );
    let mut s = std::collections::BTreeMap::new();
    s.insert("enabled".to_string(), Json::Bool(summary.enabled));
    s.insert("sends".to_string(), Json::Num(summary.sends as f64));
    s.insert("drops".to_string(), Json::Num(summary.drops as f64));
    s.insert("superseded".to_string(), Json::Num(summary.superseded as f64));
    s.insert("straggler_delayed".to_string(), Json::Num(summary.straggler_delayed as f64));
    s.insert("modeled_bytes".to_string(), Json::Num(summary.modeled_bytes as f64));
    s.insert("measured_bytes".to_string(), Json::Num(summary.measured_bytes as f64));
    s.insert(
        "fresh_payload_cells".to_string(),
        Json::Num(summary.fresh_payload_cells as f64),
    );
    s.insert("total_phase_secs".to_string(), Json::Num(summary.total_phase_secs));
    obj.insert("summary".to_string(), Json::Obj(s));
    Json::Obj(obj)
}

/// Round record `i` of `metrics` as a [`Json`] value (one trace line).
pub fn trace_round_json(metrics: &RunMetrics, i: usize) -> Json {
    let mut obj = std::collections::BTreeMap::new();
    obj.insert("round".to_string(), Json::Num(metrics.rounds[i] as f64));
    obj.insert("grad_iterations".to_string(), Json::Num(metrics.grad_iterations[i] as f64));
    obj.insert("objective".to_string(), Json::Num(metrics.objective[i]));
    obj.insert("grad_norm".to_string(), Json::Num(metrics.grad_norm[i]));
    obj.insert("consensus_error".to_string(), Json::Num(metrics.consensus_error[i]));
    obj.insert("bytes_cumulative".to_string(), Json::Num(metrics.bytes_cumulative[i] as f64));
    obj.insert(
        "measured_bytes_cumulative".to_string(),
        Json::Num(metrics.measured_bytes_cumulative[i] as f64),
    );
    obj.insert("max_transmitted".to_string(), Json::Num(metrics.max_transmitted[i]));
    obj.insert("saturations".to_string(), Json::Num(metrics.saturations[i]));
    Json::Obj(obj)
}

/// Stream a full trace into `writer`: meta line, then one line per
/// recorded round.
pub fn write_trace_to<W: Write>(
    writer: &mut W,
    metrics: &RunMetrics,
    summary: &TelemetrySummary,
) -> io::Result<()> {
    writeln!(writer, "{}", trace_meta_json(metrics, summary).to_string())?;
    for i in 0..metrics.len() {
        writeln!(writer, "{}", trace_round_json(metrics, i).to_string())?;
    }
    Ok(())
}

/// Write a trace file at `path` (buffered; overwrites).
pub fn write_trace(
    path: &Path,
    metrics: &RunMetrics,
    summary: &TelemetrySummary,
) -> io::Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    write_trace_to(&mut w, metrics, summary)?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RoundRecord;
    use crate::util::json;

    fn sample_metrics() -> RunMetrics {
        let mut m = RunMetrics::default();
        for (k, r) in [(10usize, 0usize), (20, 1)] {
            m.push(RoundRecord {
                round: k,
                grad_iterations: k,
                objective: 1.5 - r as f64,
                grad_norm: 1e-3,
                consensus_error: 2e-4,
                bytes_cumulative: 100 * (r + 1),
                measured_bytes_cumulative: 90 * (r + 1),
                max_transmitted: 3.25,
                saturations: 0,
            });
        }
        m
    }

    #[test]
    fn round_record_json_round_trips() {
        let m = sample_metrics();
        let line = trace_round_json(&m, 1).to_string();
        let parsed = json::parse(&line).expect("round line parses");
        assert_eq!(parsed.get("round").and_then(Json::as_usize), Some(20));
        assert_eq!(parsed.get("bytes_cumulative").and_then(Json::as_usize), Some(200));
        assert_eq!(
            parsed.get("measured_bytes_cumulative").and_then(Json::as_usize),
            Some(180)
        );
        assert_eq!(parsed.get("objective").and_then(Json::as_f64), Some(0.5));
    }

    #[test]
    fn meta_line_carries_schema_and_phases() {
        let m = sample_metrics();
        let mut summary = TelemetrySummary::default();
        summary.enabled = true;
        summary.phases.push(super::super::PhaseStat {
            name: "send",
            total_secs: 0.25,
            count: 40,
        });
        summary.total_phase_secs = 0.25;
        let meta = trace_meta_json(&m, &summary).to_string();
        let parsed = json::parse(&meta).expect("meta parses");
        assert_eq!(parsed.get("schema").and_then(Json::as_str), Some("adcdgd-trace"));
        assert_eq!(
            parsed.get("version").and_then(Json::as_usize),
            Some(TRACE_SCHEMA_VERSION as usize)
        );
        assert_eq!(parsed.get("rows").and_then(Json::as_usize), Some(2));
        let phases = parsed.get("phases").and_then(Json::as_arr).expect("phases array");
        assert_eq!(phases.len(), 1);
        assert_eq!(phases[0].get("name").and_then(Json::as_str), Some("send"));
        assert_eq!(phases[0].get("count").and_then(Json::as_usize), Some(40));
    }

    #[test]
    fn stream_writes_one_line_per_row_plus_meta() {
        let m = sample_metrics();
        let summary = TelemetrySummary::default();
        let mut buf = Vec::new();
        write_trace_to(&mut buf, &m, &summary).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 1 + m.len());
        // Round indices strictly increase across data lines.
        let mut prev = 0usize;
        for line in &lines[1..] {
            let parsed = json::parse(line).unwrap();
            let round = parsed.get("round").and_then(Json::as_usize).unwrap();
            assert!(round > prev, "rounds must be monotone");
            prev = round;
        }
    }
}
