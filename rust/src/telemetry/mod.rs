//! The telemetry plane: zero-alloc tracing, per-plane counters, and
//! run-trace export.
//!
//! Eight planes already count things privately — the [`Bus`] meters
//! per-link messages/drops/bytes, the mailbox plane counts supersedes,
//! the payload pools count fresh cells, the churn driver counts faults.
//! This module is the cross-cutting layer that makes those numbers
//! *observable on a live run* without perturbing it:
//!
//! * [`Registry`] — typed counters/gauges/histograms, pre-registered at
//!   build time and updated by plain `Cell` stores (zero steady-state
//!   allocation, asserted by the `ADCDGD_BENCH_ONLY=telemetry` hotpath
//!   section), with a Prometheus-style [`Registry::render_text`].
//! * [`PhaseTimers`] — span-style wall-clock timers over the engine
//!   round loop (the dim engine's seven A–E2 phases; coordinator
//!   barrier segments in threaded/pool; compress/broadcast/deliver/
//!   consume/reclaim/observe in sequential). Timing is strictly
//!   observational: it never feeds the simulated clock or the golden
//!   trajectories, and the bit-identity suites pass with telemetry on
//!   or off.
//! * [`TelemetrySummary`] — the per-run rollup ([`RunOutput::telemetry`])
//!   unifying phase time, fleet counters, and per-node send/receive
//!   rollups harvested from the planes after the engine returns.
//! * [`trace`] — `--trace out.jsonl` export: schema-versioned JSON
//!   Lines, one object per recorded round, byte columns identical to
//!   [`RunOutput::metrics`] by construction.
//!
//! Lifecycle: the driver builds one [`PhaseTimers`] per run when
//! [`RunConfig::telemetry`] is on (the default; CLI `--no-telemetry`),
//! threads it through the engine as `Option<&PhaseTimers>`, and
//! harvests everything into a [`TelemetrySummary`] at run end. Engines
//! bind their own phase-name table ([`PhaseTimers::bind`]), so dim's
//! silent pool fallback reports pool's phases, not a mislabeled table.
//!
//! [`Bus`]: crate::network::Bus
//! [`RunOutput::telemetry`]: crate::coordinator::RunOutput::telemetry
//! [`RunOutput::metrics`]: crate::coordinator::RunOutput::metrics
//! [`RunConfig::telemetry`]: crate::coordinator::RunConfig::telemetry

pub mod phases;
pub mod registry;
pub mod trace;

pub use phases::{PhaseTimers, DIM_PHASES, MAX_PHASES, SEQUENTIAL_PHASES, WORKER_PHASES};
pub use registry::{CounterId, GaugeId, HistogramId, Registry};
pub use trace::{write_trace, TRACE_COLUMNS, TRACE_SCHEMA_VERSION};

use std::fmt::Write as _;

/// One phase's accumulated wall time in a [`TelemetrySummary`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseStat {
    /// Phase name from the engine's table (see [`phases`] docs).
    pub name: &'static str,
    /// Accumulated wall seconds across the run.
    pub total_secs: f64,
    /// Spans recorded (≈ rounds, or rounds × nodes for the sequential
    /// per-node phases).
    pub count: u64,
}

/// Per-node rollup of the [`Bus`]'s per-link counters plus the mailbox
/// plane's supersede attribution.
///
/// [`Bus`]: crate::network::Bus
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NodeRollup {
    /// Messages this node put on the wire (sum over outgoing links).
    pub sends: u64,
    /// Of those, messages the loss model dropped.
    pub drops: u64,
    /// Modeled payload bytes sent.
    pub modeled_bytes: u64,
    /// Measured wire bytes sent (0 when `measure_wire` is off).
    pub measured_bytes: u64,
    /// Messages superseded *in this node's inbox* (freshest-wins
    /// overwrites; only possible under per-message delays).
    pub superseded_in: u64,
}

/// The per-run telemetry rollup surfaced as
/// [`crate::coordinator::RunOutput::telemetry`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TelemetrySummary {
    /// Whether telemetry was enabled for the run
    /// ([`crate::coordinator::RunConfig::telemetry`]). When `false`,
    /// every field below is zero/empty.
    pub enabled: bool,
    /// Phase wall-time rows in the engine's table order.
    pub phases: Vec<PhaseStat>,
    /// Sum of `phases[*].total_secs`.
    pub total_phase_secs: f64,
    /// Fleet-total messages put on the wire.
    pub sends: u64,
    /// Fleet-total messages dropped by the loss model. Churn
    /// dead/link-down suppressions are counted separately, in
    /// [`crate::coordinator::ChurnCounters`].
    pub drops: u64,
    /// Fleet-total mailbox supersedes (freshest-wins overwrites).
    pub superseded: u64,
    /// Broadcasts delayed by a straggler schedule.
    pub straggler_delayed: u64,
    /// Fleet-total modeled payload bytes.
    pub modeled_bytes: u64,
    /// Fleet-total measured wire bytes (0 with `measure_wire` off).
    pub measured_bytes: u64,
    /// Payload-pool cells created across the engine's pools (the
    /// encode-plane recycling health signal; engine-dependent because
    /// pools shard per worker).
    pub fresh_payload_cells: u64,
    /// Per-node send/receive rollups, indexed by node id.
    pub node_rollups: Vec<NodeRollup>,
}

impl TelemetrySummary {
    /// The `k` phases with the largest accumulated wall time,
    /// descending.
    pub fn top_phases(&self, k: usize) -> Vec<PhaseStat> {
        let mut sorted = self.phases.clone();
        sorted.sort_by(|a, b| b.total_secs.total_cmp(&a.total_secs));
        sorted.truncate(k);
        sorted
    }

    /// Measured-to-modeled byte ratio, or `None` when either column is
    /// zero (wire metering off, or nothing sent).
    pub fn wire_ratio(&self) -> Option<f64> {
        if self.modeled_bytes == 0 || self.measured_bytes == 0 {
            None
        } else {
            Some(self.measured_bytes as f64 / self.modeled_bytes as f64)
        }
    }

    /// One-line human summary printed by `solve`: total phase time, the
    /// top-3 phases, and the measured/modeled byte ratio.
    pub fn render_line(&self) -> String {
        if !self.enabled {
            return "telemetry off".to_string();
        }
        let mut line = format!("telemetry phase_time={:.3}s", self.total_phase_secs);
        let top = self.top_phases(3);
        if !top.is_empty() {
            line.push_str(" top=[");
            for (i, p) in top.iter().enumerate() {
                if i > 0 {
                    line.push(' ');
                }
                let _ = write!(line, "{}:{:.3}s", p.name, p.total_secs);
            }
            line.push(']');
        }
        match self.wire_ratio() {
            Some(r) => {
                let _ = write!(line, " wire/modeled={r:.3}");
            }
            None => line.push_str(" wire/modeled=-"),
        }
        line
    }

    /// Dump the rollup into a [`Registry`] (fleet counters + one
    /// histogram-free gauge per phase) and render it as Prometheus
    /// text. Convenience for callers that want a scrapeable snapshot
    /// without keeping a registry alive during the run.
    pub fn render_text(&self) -> String {
        let mut r = Registry::new();
        let sends = r.counter("adcdgd_sends_total");
        let drops = r.counter("adcdgd_drops_total");
        let superseded = r.counter("adcdgd_superseded_total");
        let stragglers = r.counter("adcdgd_straggler_delayed_total");
        let modeled = r.counter("adcdgd_modeled_bytes_total");
        let measured = r.counter("adcdgd_measured_bytes_total");
        let cells = r.counter("adcdgd_fresh_payload_cells_total");
        let phase_ids: Vec<_> = self
            .phases
            .iter()
            .map(|p| r.gauge(&format!("adcdgd_phase_seconds{{phase=\"{}\"}}", p.name)))
            .collect();
        r.seal();
        r.store(sends, self.sends);
        r.store(drops, self.drops);
        r.store(superseded, self.superseded);
        r.store(stragglers, self.straggler_delayed);
        r.store(modeled, self.modeled_bytes);
        r.store(measured, self.measured_bytes);
        r.store(cells, self.fresh_payload_cells);
        for (p, id) in self.phases.iter().zip(phase_ids) {
            r.set_gauge(id, p.total_secs);
        }
        r.render_text()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summary() -> TelemetrySummary {
        TelemetrySummary {
            enabled: true,
            phases: vec![
                PhaseStat { name: "send", total_secs: 0.1, count: 10 },
                PhaseStat { name: "deliver_consume", total_secs: 0.3, count: 10 },
                PhaseStat { name: "observe", total_secs: 0.05, count: 10 },
            ],
            total_phase_secs: 0.45,
            sends: 100,
            drops: 7,
            superseded: 2,
            straggler_delayed: 0,
            modeled_bytes: 1000,
            measured_bytes: 430,
            fresh_payload_cells: 12,
            node_rollups: vec![NodeRollup::default(); 4],
        }
    }

    #[test]
    fn top_phases_sorts_descending() {
        let s = summary();
        let top = s.top_phases(2);
        assert_eq!(top[0].name, "deliver_consume");
        assert_eq!(top[1].name, "send");
    }

    #[test]
    fn render_line_mentions_ratio_and_top_phase() {
        let s = summary();
        let line = s.render_line();
        assert!(line.contains("deliver_consume:0.300s"), "{line}");
        assert!(line.contains("wire/modeled=0.430"), "{line}");
        assert_eq!(TelemetrySummary::default().render_line(), "telemetry off");
    }

    #[test]
    fn render_text_exposes_fleet_counters() {
        let text = summary().render_text();
        assert!(text.contains("adcdgd_sends_total 100"));
        assert!(text.contains("adcdgd_measured_bytes_total 430"));
        assert!(text.contains("adcdgd_phase_seconds{phase=\"send\"} 0.1"));
    }
}
