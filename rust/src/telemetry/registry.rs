//! Fixed-capacity metric registry: counters, gauges, histograms.
//!
//! The registry follows the crate's arena discipline: every metric is
//! **pre-registered** while the fleet is being built (registration
//! pushes into `Vec`s and may allocate), then the registry is
//! [`Registry::seal`]ed and the hot path only performs plain
//! `u64`/`f64` stores through [`Cell`]s — no locks, no hashing, no
//! allocation. Ids are index newtypes handed out at registration, so a
//! hot-path update is one bounds-checked array store.
//!
//! Concurrency contract: the registry is written by **one thread** —
//! the sequential engine's caller or the parallel engines' coordinator
//! thread. Worker threads never touch it (`Cell` is deliberately
//! `!Sync`, so the compiler enforces this; see [`super::PhaseTimers`]
//! for the same rule on timers).
//!
//! [`Registry::render_text`] snapshots everything in the Prometheus
//! text exposition format for scraping or diffing.

use std::cell::Cell;
use std::fmt::Write as _;

/// Handle to a registered monotone counter (`u64`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CounterId(usize);

/// Handle to a registered gauge (`f64`, last-write-wins).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GaugeId(usize);

/// Handle to a registered histogram (fixed bucket bounds).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HistogramId(usize);

struct Counter {
    name: String,
    value: Cell<u64>,
}

struct Gauge {
    name: String,
    value: Cell<f64>,
}

struct Histogram {
    name: String,
    /// Upper bounds of the finite buckets (ascending); one implicit
    /// `+Inf` bucket follows.
    bounds: Vec<f64>,
    /// `counts[i]` counts observations `<= bounds[i]`; the last entry
    /// is the `+Inf` bucket. Length `bounds.len() + 1`.
    counts: Vec<Cell<u64>>,
    sum: Cell<f64>,
    total: Cell<u64>,
}

/// Pre-registered, fixed-capacity metric store (see module docs).
#[derive(Default)]
pub struct Registry {
    counters: Vec<Counter>,
    gauges: Vec<Gauge>,
    histograms: Vec<Histogram>,
    sealed: bool,
}

impl Registry {
    /// Empty, unsealed registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a monotone counter. Panics after [`Registry::seal`] —
    /// registration is a build-time activity by contract.
    pub fn counter(&mut self, name: &str) -> CounterId {
        assert!(!self.sealed, "telemetry: counter {name:?} registered after seal");
        self.counters.push(Counter { name: name.to_string(), value: Cell::new(0) });
        CounterId(self.counters.len() - 1)
    }

    /// Register a gauge. Panics after [`Registry::seal`].
    pub fn gauge(&mut self, name: &str) -> GaugeId {
        assert!(!self.sealed, "telemetry: gauge {name:?} registered after seal");
        self.gauges.push(Gauge { name: name.to_string(), value: Cell::new(0.0) });
        GaugeId(self.gauges.len() - 1)
    }

    /// Register a histogram with ascending finite bucket `bounds` (an
    /// implicit `+Inf` bucket is appended). Panics after
    /// [`Registry::seal`] or on non-ascending bounds.
    pub fn histogram(&mut self, name: &str, bounds: &[f64]) -> HistogramId {
        assert!(!self.sealed, "telemetry: histogram {name:?} registered after seal");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "telemetry: histogram {name:?} bounds must ascend"
        );
        self.histograms.push(Histogram {
            name: name.to_string(),
            bounds: bounds.to_vec(),
            counts: (0..=bounds.len()).map(|_| Cell::new(0)).collect(),
            sum: Cell::new(0.0),
            total: Cell::new(0),
        });
        HistogramId(self.histograms.len() - 1)
    }

    /// Freeze registration; hot-path updates only from here on.
    pub fn seal(&mut self) {
        self.sealed = true;
    }

    /// Whether [`Registry::seal`] has been called.
    pub fn sealed(&self) -> bool {
        self.sealed
    }

    /// Add `v` to a counter (plain `Cell` store — zero-alloc).
    #[inline]
    pub fn add(&self, id: CounterId, v: u64) {
        let c = &self.counters[id.0].value;
        c.set(c.get() + v);
    }

    /// Overwrite a counter with an externally accumulated total (used
    /// when harvesting counts another plane already keeps).
    #[inline]
    pub fn store(&self, id: CounterId, v: u64) {
        self.counters[id.0].value.set(v);
    }

    /// Current value of a counter.
    pub fn get(&self, id: CounterId) -> u64 {
        self.counters[id.0].value.get()
    }

    /// Set a gauge (last write wins).
    #[inline]
    pub fn set_gauge(&self, id: GaugeId, v: f64) {
        self.gauges[id.0].value.set(v);
    }

    /// Current value of a gauge.
    pub fn gauge_value(&self, id: GaugeId) -> f64 {
        self.gauges[id.0].value.get()
    }

    /// Record one observation into a histogram (zero-alloc: a linear
    /// scan over the fixed bounds and three `Cell` stores).
    #[inline]
    pub fn observe(&self, id: HistogramId, v: f64) {
        let h = &self.histograms[id.0];
        let mut i = h.bounds.len(); // +Inf bucket by default
        for (b, bound) in h.bounds.iter().enumerate() {
            if v <= *bound {
                i = b;
                break;
            }
        }
        let c = &h.counts[i];
        c.set(c.get() + 1);
        h.sum.set(h.sum.get() + v);
        h.total.set(h.total.get() + 1);
    }

    /// Observation count of a histogram.
    pub fn histogram_count(&self, id: HistogramId) -> u64 {
        self.histograms[id.0].total.get()
    }

    /// Sum of a histogram's observations.
    pub fn histogram_sum(&self, id: HistogramId) -> f64 {
        self.histograms[id.0].sum.get()
    }

    /// Render every metric in the Prometheus text exposition format
    /// (counters as `# TYPE ... counter`, histograms with cumulative
    /// `_bucket{le="..."}` rows plus `_sum`/`_count`). Allocates — call
    /// off the hot path.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for c in &self.counters {
            let _ = writeln!(out, "# TYPE {} counter", c.name);
            let _ = writeln!(out, "{} {}", c.name, c.value.get());
        }
        for g in &self.gauges {
            let _ = writeln!(out, "# TYPE {} gauge", g.name);
            let _ = writeln!(out, "{} {}", g.name, g.value.get());
        }
        for h in &self.histograms {
            let _ = writeln!(out, "# TYPE {} histogram", h.name);
            let mut cum = 0u64;
            for (i, bound) in h.bounds.iter().enumerate() {
                cum += h.counts[i].get();
                let _ = writeln!(out, "{}_bucket{{le=\"{}\"}} {}", h.name, bound, cum);
            }
            cum += h.counts[h.bounds.len()].get();
            let _ = writeln!(out, "{}_bucket{{le=\"+Inf\"}} {}", h.name, cum);
            let _ = writeln!(out, "{}_sum {}", h.name, h.sum.get());
            let _ = writeln!(out, "{}_count {}", h.name, h.total.get());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_round_trip() {
        let mut r = Registry::new();
        let sends = r.counter("adcdgd_sends_total");
        let ratio = r.gauge("adcdgd_wire_ratio");
        r.seal();
        r.add(sends, 3);
        r.add(sends, 4);
        r.store(sends, 10);
        r.set_gauge(ratio, 0.5);
        assert_eq!(r.get(sends), 10);
        assert_eq!(r.gauge_value(ratio), 0.5);
        let text = r.render_text();
        assert!(text.contains("# TYPE adcdgd_sends_total counter"));
        assert!(text.contains("adcdgd_sends_total 10"));
        assert!(text.contains("adcdgd_wire_ratio 0.5"));
    }

    #[test]
    fn histogram_buckets_are_cumulative() {
        let mut r = Registry::new();
        let h = r.histogram("adcdgd_phase_seconds", &[0.001, 0.01, 0.1]);
        r.seal();
        for v in [0.0005, 0.005, 0.005, 0.05, 5.0] {
            r.observe(h, v);
        }
        assert_eq!(r.histogram_count(h), 5);
        assert!((r.histogram_sum(h) - 5.0605).abs() < 1e-12);
        let text = r.render_text();
        assert!(text.contains("adcdgd_phase_seconds_bucket{le=\"0.001\"} 1"));
        assert!(text.contains("adcdgd_phase_seconds_bucket{le=\"0.01\"} 3"));
        assert!(text.contains("adcdgd_phase_seconds_bucket{le=\"0.1\"} 4"));
        assert!(text.contains("adcdgd_phase_seconds_bucket{le=\"+Inf\"} 5"));
        assert!(text.contains("adcdgd_phase_seconds_count 5"));
    }

    #[test]
    #[should_panic(expected = "registered after seal")]
    fn registration_after_seal_rejected() {
        let mut r = Registry::new();
        r.seal();
        r.counter("late");
    }
}
