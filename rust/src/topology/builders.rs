//! Standard topology constructors.

use super::Graph;
use crate::rng::Xoshiro256pp;

/// Two nodes joined by one link — the Fig. 1 motivating example.
pub fn pair() -> Graph {
    Graph::new(2, vec![(0, 1)])
}

/// Path graph `0 — 1 — … — n−1`.
pub fn path(n: usize) -> Graph {
    assert!(n >= 1);
    Graph::new(n, (0..n.saturating_sub(1)).map(|i| (i, i + 1)).collect())
}

/// Ring / circle graph (paper Fig. 9: each node connects to its two
/// neighbors). For `n = 2` this degenerates to a single link.
pub fn ring(n: usize) -> Graph {
    assert!(n >= 2, "ring needs at least 2 nodes");
    if n == 2 {
        return pair();
    }
    let mut edges: Vec<(usize, usize)> = (0..n - 1).map(|i| (i, i + 1)).collect();
    edges.push((n - 1, 0));
    Graph::new(n, edges)
}

/// Star graph: node 0 is the hub.
pub fn star(n: usize) -> Graph {
    assert!(n >= 2);
    Graph::new(n, (1..n).map(|i| (0, i)).collect())
}

/// Complete graph `K_n`.
pub fn complete(n: usize) -> Graph {
    assert!(n >= 1);
    let mut edges = Vec::with_capacity(n * (n - 1) / 2);
    for i in 0..n {
        for j in (i + 1)..n {
            edges.push((i, j));
        }
    }
    Graph::new(n, edges)
}

/// `rows × cols` 2-D grid (4-neighborhood).
pub fn grid2d(rows: usize, cols: usize) -> Graph {
    assert!(rows >= 1 && cols >= 1);
    let id = |r: usize, c: usize| r * cols + c;
    let mut edges = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                edges.push((id(r, c), id(r, c + 1)));
            }
            if r + 1 < rows {
                edges.push((id(r, c), id(r + 1, c)));
            }
        }
    }
    Graph::new(rows * cols, edges)
}

/// The paper's Fig. 3 four-node topology: node 0 is connected to 1, 2, 3
/// (matching the consensus matrix of Fig. 4 whose off-diagonal sparsity is
/// row 0 ↔ all others).
pub fn paper_four_node() -> Graph {
    Graph::new(4, vec![(0, 1), (0, 2), (0, 3)])
}

/// Erdős–Rényi `G(n, p)`, conditioned on connectivity: edges are resampled
/// (with fresh randomness) until the graph is connected. Deterministic
/// given `seed`.
pub fn erdos_renyi(n: usize, p: f64, seed: u64) -> Graph {
    assert!(n >= 2);
    assert!((0.0..=1.0).contains(&p));
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    for _attempt in 0..10_000 {
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                if rng.next_f64() < p {
                    edges.push((i, j));
                }
            }
        }
        let g = Graph::new(n, edges);
        if g.is_connected() {
            return g;
        }
    }
    panic!("erdos_renyi({n}, {p}): failed to draw a connected graph in 10000 attempts");
}

/// Barabási–Albert preferential attachment with `m` links per new node.
/// Produces the scale-free degree distributions the paper's §IV-A remark
/// appeals to (most nodes low-degree ⇒ modest neighbor-memory cost).
/// Deterministic given `seed`.
pub fn barabasi_albert(n: usize, m: usize, seed: u64) -> Graph {
    assert!(m >= 1, "m must be >= 1");
    assert!(n > m, "need n > m");
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    // Start from a complete core on m+1 nodes.
    let mut edges: Vec<(usize, usize)> = Vec::new();
    for i in 0..=m {
        for j in (i + 1)..=m {
            edges.push((i, j));
        }
    }
    // Repeated-endpoint list: node appears once per incident edge ⇒
    // sampling uniformly from it is preferential attachment.
    let mut endpoints: Vec<usize> = Vec::new();
    for &(u, v) in &edges {
        endpoints.push(u);
        endpoints.push(v);
    }
    for new in (m + 1)..n {
        let mut targets: Vec<usize> = Vec::new();
        while targets.len() < m {
            let t = endpoints[rng.next_bounded(endpoints.len() as u64) as usize];
            if !targets.contains(&t) {
                targets.push(t);
            }
        }
        for &t in &targets {
            edges.push((t, new));
            endpoints.push(t);
            endpoints.push(new);
        }
    }
    Graph::new(n, edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_structure() {
        let g = ring(5);
        assert_eq!(g.num_edges(), 5);
        for i in 0..5 {
            assert_eq!(g.degree(i), 2);
        }
        assert!(g.is_connected());
        let g2 = ring(2);
        assert_eq!(g2.num_edges(), 1);
    }

    #[test]
    fn star_structure() {
        let g = star(6);
        assert_eq!(g.degree(0), 5);
        for i in 1..6 {
            assert_eq!(g.degree(i), 1);
        }
        assert!(g.is_connected());
    }

    #[test]
    fn complete_structure() {
        let g = complete(5);
        assert_eq!(g.num_edges(), 10);
        assert_eq!(g.diameter(), Some(1));
    }

    #[test]
    fn grid_structure() {
        let g = grid2d(3, 4);
        assert_eq!(g.num_nodes(), 12);
        // edges: 3*3 horizontal + 2*4 vertical = 9 + 8 = 17
        assert_eq!(g.num_edges(), 17);
        assert!(g.is_connected());
    }

    #[test]
    fn paper_four_node_matches_consensus_sparsity() {
        let g = paper_four_node();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.degree(0), 3);
        for i in 1..4 {
            assert_eq!(g.degree(i), 1);
            assert!(g.has_edge(0, i));
        }
    }

    #[test]
    fn erdos_renyi_connected_and_deterministic() {
        let a = erdos_renyi(12, 0.3, 7);
        let b = erdos_renyi(12, 0.3, 7);
        assert!(a.is_connected());
        assert_eq!(a.edges(), b.edges());
        let c = erdos_renyi(12, 0.3, 8);
        // Overwhelmingly likely to differ.
        assert_ne!(a.edges(), c.edges());
    }

    #[test]
    fn barabasi_albert_structure() {
        let g = barabasi_albert(30, 2, 5);
        assert_eq!(g.num_nodes(), 30);
        assert!(g.is_connected());
        // Core K3 (3 edges) + 27 new nodes × 2 = 57 edges.
        assert_eq!(g.num_edges(), 3 + 27 * 2);
        // Determinism
        let h = barabasi_albert(30, 2, 5);
        assert_eq!(g.edges(), h.edges());
    }

    #[test]
    fn path_structure() {
        let g = path(4);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.diameter(), Some(3));
        let single = path(1);
        assert_eq!(single.num_edges(), 0);
    }
}
