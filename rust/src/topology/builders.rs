//! Standard topology constructors.

use super::Graph;
use crate::rng::Xoshiro256pp;

/// Two nodes joined by one link — the Fig. 1 motivating example.
pub fn pair() -> Graph {
    Graph::new(2, vec![(0, 1)])
}

/// Path graph `0 — 1 — … — n−1`.
pub fn path(n: usize) -> Graph {
    assert!(n >= 1);
    Graph::new(n, (0..n.saturating_sub(1)).map(|i| (i, i + 1)).collect())
}

/// Ring / circle graph (paper Fig. 9: each node connects to its two
/// neighbors). For `n = 2` this degenerates to a single link.
pub fn ring(n: usize) -> Graph {
    assert!(n >= 2, "ring needs at least 2 nodes");
    if n == 2 {
        return pair();
    }
    let mut edges: Vec<(usize, usize)> = (0..n - 1).map(|i| (i, i + 1)).collect();
    edges.push((n - 1, 0));
    Graph::new(n, edges)
}

/// Star graph: node 0 is the hub.
pub fn star(n: usize) -> Graph {
    assert!(n >= 2);
    Graph::new(n, (1..n).map(|i| (0, i)).collect())
}

/// Complete graph `K_n`.
pub fn complete(n: usize) -> Graph {
    assert!(n >= 1);
    let mut edges = Vec::with_capacity(n * (n - 1) / 2);
    for i in 0..n {
        for j in (i + 1)..n {
            edges.push((i, j));
        }
    }
    Graph::new(n, edges)
}

/// `rows × cols` 2-D grid (4-neighborhood).
pub fn grid2d(rows: usize, cols: usize) -> Graph {
    assert!(rows >= 1 && cols >= 1);
    let id = |r: usize, c: usize| r * cols + c;
    let mut edges = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                edges.push((id(r, c), id(r, c + 1)));
            }
            if r + 1 < rows {
                edges.push((id(r, c), id(r + 1, c)));
            }
        }
    }
    Graph::new(rows * cols, edges)
}

/// The paper's Fig. 3 four-node topology: node 0 is connected to 1, 2, 3
/// (matching the consensus matrix of Fig. 4 whose off-diagonal sparsity is
/// row 0 ↔ all others).
pub fn paper_four_node() -> Graph {
    Graph::new(4, vec![(0, 1), (0, 2), (0, 3)])
}

/// Erdős–Rényi `G(n, p)`, conditioned on connectivity: edges are resampled
/// (with fresh randomness) until the graph is connected. Deterministic
/// given `seed`.
///
/// Each attempt uses Batagelj–Brandes geometric skipping: instead of one
/// Bernoulli draw per candidate pair (O(N²)), one uniform draw yields the
/// geometrically-distributed gap to the next present edge, so an attempt
/// costs expected O(E + N). At `p = 1` the skip is always zero and the
/// complete graph falls out naturally.
pub fn erdos_renyi(n: usize, p: f64, seed: u64) -> Graph {
    assert!(n >= 2);
    assert!((0.0..=1.0).contains(&p));
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    // ln(1−p): −∞ at p = 1 (skip collapses to 0), 0 at p = 0 or p below
    // f64 resolution (the skip would diverge — every such attempt is the
    // empty graph, which the connectivity loop rejects below exactly
    // like the old sampler did).
    let log_q = (1.0 - p).ln();
    for _attempt in 0..10_000 {
        let mut edges = Vec::new();
        if log_q < 0.0 {
            // Walk candidate pairs (w, v) with w < v in column-major
            // order, jumping `skip` candidates at a time.
            let mut v: usize = 1;
            let mut w: i64 = -1;
            while v < n {
                let skip = ((1.0 - rng.next_f64()).ln() / log_q).floor() as i64;
                w += 1 + skip;
                while w >= v as i64 && v < n {
                    w -= v as i64;
                    v += 1;
                }
                if v < n {
                    edges.push((w as usize, v));
                }
            }
        }
        let g = Graph::new(n, edges);
        if g.is_connected() {
            return g;
        }
    }
    panic!("erdos_renyi({n}, {p}): failed to draw a connected graph in 10000 attempts");
}

/// Random geometric graph on the unit square: `n` uniform points, an edge
/// whenever two points are within `radius`; resampled until connected.
/// Neighbor search uses grid-cell bucketing (cells of side ≥ `radius`,
/// each cell compared against its half-stencil), so an attempt costs
/// expected O(N + E) rather than O(N²). Deterministic given `seed`.
pub fn random_geometric(n: usize, radius: f64, seed: u64) -> Graph {
    assert!(n >= 2);
    assert!(radius > 0.0, "radius must be positive");
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    // Cell side must stay ≥ radius for the 3×3 stencil to be exhaustive;
    // shrinking the cell count (≤ √n keeps the counting arrays O(N))
    // only enlarges cells, so correctness is preserved.
    let cells = ((1.0 / radius).floor() as usize)
        .min((n as f64).sqrt().ceil() as usize)
        .max(1);
    let r2 = radius * radius;
    let cell_of = |x: f64| ((x * cells as f64) as usize).min(cells - 1);
    for _attempt in 0..10_000 {
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            xs.push(rng.next_f64());
            ys.push(rng.next_f64());
        }
        // Counting-sort point ids into cells (stable: ascending id within
        // a cell), so edge discovery order is deterministic.
        let mut counts = vec![0usize; cells * cells];
        for i in 0..n {
            counts[cell_of(ys[i]) * cells + cell_of(xs[i])] += 1;
        }
        let mut starts = vec![0usize; cells * cells + 1];
        for c in 0..cells * cells {
            starts[c + 1] = starts[c] + counts[c];
        }
        let mut bucket = vec![0usize; n];
        let mut cursor = starts.clone();
        for i in 0..n {
            let c = cell_of(ys[i]) * cells + cell_of(xs[i]);
            bucket[cursor[c]] = i;
            cursor[c] += 1;
        }
        let mut edges = Vec::new();
        let mut push_close = |a: usize, b: usize, edges: &mut Vec<(usize, usize)>| {
            let (dx, dy) = (xs[a] - xs[b], ys[a] - ys[b]);
            if dx * dx + dy * dy <= r2 {
                edges.push((a.min(b), a.max(b)));
            }
        };
        for cy in 0..cells {
            for cx in 0..cells {
                let c = cy * cells + cx;
                let own = &bucket[starts[c]..starts[c + 1]];
                for (s, &a) in own.iter().enumerate() {
                    for &b in &own[s + 1..] {
                        push_close(a, b, &mut edges);
                    }
                }
                // Half-stencil: E, S, SE, SW — every adjacent cell pair
                // is visited exactly once.
                for (ox, oy) in [(1i64, 0i64), (0, 1), (1, 1), (-1, 1)] {
                    let (nx, ny) = (cx as i64 + ox, cy as i64 + oy);
                    if nx < 0 || ny < 0 || nx >= cells as i64 || ny >= cells as i64 {
                        continue;
                    }
                    let d = ny as usize * cells + nx as usize;
                    for &a in own {
                        for &b in &bucket[starts[d]..starts[d + 1]] {
                            push_close(a, b, &mut edges);
                        }
                    }
                }
            }
        }
        let g = Graph::new(n, edges);
        if g.is_connected() {
            return g;
        }
    }
    panic!("random_geometric({n}, {radius}): failed to draw a connected graph in 10000 attempts");
}

/// Random `k`-regular graph via the pairing (configuration) model:
/// `n·k` stubs are shuffled and paired off; a pairing that would create a
/// self-loop or duplicate edge is repaired by swapping in a random stub
/// from the unconsumed suffix. Resampled until simple and connected.
/// Expected O(N·k) per attempt; deterministic given `seed`.
pub fn k_regular(n: usize, k: usize, seed: u64) -> Graph {
    assert!(n >= 2);
    assert!(k >= 1 && k < n, "need 1 <= k < n");
    assert!(n * k % 2 == 0, "n*k must be even");
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let total = n * k;
    'attempt: for _ in 0..10_000 {
        let mut stubs: Vec<usize> = (0..total).map(|t| t / k).collect();
        // Fisher–Yates on the stub list.
        for i in (1..total).rev() {
            let j = rng.next_bounded(i as u64 + 1) as usize;
            stubs.swap(i, j);
        }
        let mut adj: Vec<Vec<usize>> = (0..n).map(|_| Vec::with_capacity(k)).collect();
        let mut edges = Vec::with_capacity(total / 2);
        for a in (0..total).step_by(2) {
            let mut tries = 0;
            loop {
                let (u, v) = (stubs[a], stubs[a + 1]);
                // Linear membership probe: k is small, rows are short.
                if u != v && !adj[u].contains(&v) {
                    adj[u].push(v);
                    adj[v].push(u);
                    edges.push((u.min(v), u.max(v)));
                    break;
                }
                // Repair: swap the partner stub with a random stub from
                // the unconsumed suffix; if none is left (or repair
                // stalls), restart the whole attempt.
                if a + 2 >= total || tries >= 64 {
                    continue 'attempt;
                }
                tries += 1;
                let j = a + 2 + rng.next_bounded((total - a - 2) as u64) as usize;
                stubs.swap(a + 1, j);
            }
        }
        let g = Graph::new(n, edges);
        if g.is_connected() {
            return g;
        }
    }
    panic!("k_regular({n}, {k}): failed to draw a connected simple graph in 10000 attempts");
}

/// Barabási–Albert preferential attachment with `m` links per new node.
/// Produces the scale-free degree distributions the paper's §IV-A remark
/// appeals to (most nodes low-degree ⇒ modest neighbor-memory cost).
/// Deterministic given `seed`.
pub fn barabasi_albert(n: usize, m: usize, seed: u64) -> Graph {
    assert!(m >= 1, "m must be >= 1");
    assert!(n > m, "need n > m");
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    // Start from a complete core on m+1 nodes.
    let mut edges: Vec<(usize, usize)> = Vec::new();
    for i in 0..=m {
        for j in (i + 1)..=m {
            edges.push((i, j));
        }
    }
    // Repeated-endpoint list: node appears once per incident edge ⇒
    // sampling uniformly from it is preferential attachment.
    let mut endpoints: Vec<usize> = Vec::new();
    for &(u, v) in &edges {
        endpoints.push(u);
        endpoints.push(v);
    }
    // `targets` keeps draw order (the accept/reject sequence feeds the
    // RNG stream, so it is what pins per-seed graphs); `probe` is the
    // same set kept sorted so membership is a binary search instead of
    // an O(m) scan per draw.
    let mut targets: Vec<usize> = Vec::with_capacity(m);
    let mut probe: Vec<usize> = Vec::with_capacity(m);
    for new in (m + 1)..n {
        targets.clear();
        probe.clear();
        while targets.len() < m {
            let t = endpoints[rng.next_bounded(endpoints.len() as u64) as usize];
            if let Err(pos) = probe.binary_search(&t) {
                probe.insert(pos, t);
                targets.push(t);
            }
        }
        for &t in &targets {
            edges.push((t, new));
            endpoints.push(t);
            endpoints.push(new);
        }
    }
    Graph::new(n, edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_structure() {
        let g = ring(5);
        assert_eq!(g.num_edges(), 5);
        for i in 0..5 {
            assert_eq!(g.degree(i), 2);
        }
        assert!(g.is_connected());
        let g2 = ring(2);
        assert_eq!(g2.num_edges(), 1);
    }

    #[test]
    fn star_structure() {
        let g = star(6);
        assert_eq!(g.degree(0), 5);
        for i in 1..6 {
            assert_eq!(g.degree(i), 1);
        }
        assert!(g.is_connected());
    }

    #[test]
    fn complete_structure() {
        let g = complete(5);
        assert_eq!(g.num_edges(), 10);
        assert_eq!(g.diameter(), Some(1));
    }

    #[test]
    fn grid_structure() {
        let g = grid2d(3, 4);
        assert_eq!(g.num_nodes(), 12);
        // edges: 3*3 horizontal + 2*4 vertical = 9 + 8 = 17
        assert_eq!(g.num_edges(), 17);
        assert!(g.is_connected());
    }

    #[test]
    fn paper_four_node_matches_consensus_sparsity() {
        let g = paper_four_node();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.degree(0), 3);
        for i in 1..4 {
            assert_eq!(g.degree(i), 1);
            assert!(g.has_edge(0, i));
        }
    }

    #[test]
    fn erdos_renyi_connected_and_deterministic() {
        let a = erdos_renyi(12, 0.3, 7);
        let b = erdos_renyi(12, 0.3, 7);
        assert!(a.is_connected());
        assert_eq!(a.edges(), b.edges());
        let c = erdos_renyi(12, 0.3, 8);
        // Overwhelmingly likely to differ.
        assert_ne!(a.edges(), c.edges());
    }

    #[test]
    fn barabasi_albert_structure() {
        let g = barabasi_albert(30, 2, 5);
        assert_eq!(g.num_nodes(), 30);
        assert!(g.is_connected());
        // Core K3 (3 edges) + 27 new nodes × 2 = 57 edges.
        assert_eq!(g.num_edges(), 3 + 27 * 2);
        // Determinism
        let h = barabasi_albert(30, 2, 5);
        assert_eq!(g.edges(), h.edges());
    }

    #[test]
    fn path_structure() {
        let g = path(4);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.diameter(), Some(3));
        let single = path(1);
        assert_eq!(single.num_edges(), 0);
    }

    /// Geometric skipping must cover the degenerate probabilities: p = 1
    /// is the complete graph (skip always 0), and large-p draws stay
    /// connected/deterministic like the old per-pair sampler.
    #[test]
    fn erdos_renyi_edge_probabilities() {
        let g = erdos_renyi(7, 1.0, 3);
        assert_eq!(g.num_edges(), 7 * 6 / 2, "p=1 must yield K_n");
        // Expected density roughly matches p (loose 3σ-ish band).
        let g = erdos_renyi(200, 0.1, 11);
        let expect = 0.1 * (200.0 * 199.0 / 2.0);
        let got = g.num_edges() as f64;
        assert!((got - expect).abs() < 0.3 * expect, "expected ~{expect}, got {got}");
    }

    #[test]
    fn random_geometric_connected_and_deterministic() {
        let a = random_geometric(60, 0.35, 4);
        let b = random_geometric(60, 0.35, 4);
        assert!(a.is_connected());
        assert_eq!(a.edges(), b.edges());
        let c = random_geometric(60, 0.35, 5);
        assert_ne!(a.edges(), c.edges());
        // Geometric locality: a tight radius on many nodes keeps the
        // graph sparse relative to complete.
        assert!(a.num_edges() < 60 * 59 / 2);
    }

    /// Bucketed neighbor search must agree exactly with the O(N²)
    /// all-pairs rule: same points ⇒ same edge set.
    #[test]
    fn random_geometric_matches_all_pairs_rule() {
        let n = 40;
        let radius = 0.3;
        let g = random_geometric(n, radius, 9);
        // Re-derive the accepted attempt's points by replaying the RNG:
        // connectivity retries consume 2n draws per attempt, so walk
        // attempts until the edge sets line up structurally.
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let expected = loop {
            let mut xs = Vec::with_capacity(n);
            let mut ys = Vec::with_capacity(n);
            for _ in 0..n {
                xs.push(rng.next_f64());
                ys.push(rng.next_f64());
            }
            let mut edges = Vec::new();
            for i in 0..n {
                for j in (i + 1)..n {
                    let (dx, dy) = (xs[i] - xs[j], ys[i] - ys[j]);
                    if dx * dx + dy * dy <= radius * radius {
                        edges.push((i, j));
                    }
                }
            }
            let cand = Graph::new(n, edges);
            if cand.is_connected() {
                break cand;
            }
        };
        assert_eq!(g.edges(), expected.edges());
    }

    #[test]
    fn k_regular_structure_and_determinism() {
        let g = k_regular(50, 4, 3);
        assert_eq!(g.num_nodes(), 50);
        assert_eq!(g.num_edges(), 50 * 4 / 2);
        for i in 0..50 {
            assert_eq!(g.degree(i), 4, "node {i}");
        }
        assert!(g.is_connected());
        let h = k_regular(50, 4, 3);
        assert_eq!(g.edges(), h.edges());
        // k = n−1 degenerates to the complete graph.
        let kc = k_regular(5, 4, 1);
        assert_eq!(kc.num_edges(), 10);
    }

    #[test]
    #[should_panic(expected = "n*k must be even")]
    fn k_regular_rejects_odd_stub_count() {
        let _ = k_regular(9, 3, 1);
    }

    /// The sorted-probe rewrite must preserve the draw sequence — same
    /// seed, same graph as the historical `targets.contains` scan.
    #[test]
    fn barabasi_albert_scales_to_large_n() {
        let n = 100_000;
        let m = 4;
        let g = barabasi_albert(n, m, 17);
        assert_eq!(g.num_nodes(), n);
        // Complete core on m+1 nodes plus m links per later node.
        assert_eq!(g.num_edges(), m * (m + 1) / 2 + (n - m - 1) * m);
        assert!(g.is_connected());
        // Preferential attachment concentrates degree on early nodes.
        assert!(g.max_degree() > 10 * m);
    }
}
