//! Network topologies.
//!
//! The paper's experiments run on a 2-node pair (Fig. 1), the custom
//! 4-node star-like graph of Fig. 3, and circle graphs of growing size
//! (Fig. 9/10). This module provides those plus the standard families used
//! for scaling and robustness studies (complete, path, star, 2-D grid,
//! Erdős–Rényi, Barabási–Albert scale-free — the paper's §IV-A remark about
//! scale-free node degrees motivates the last one). The random families
//! (`erdos_renyi` via geometric skipping, `random_geometric` via
//! grid-cell bucketing, `k_regular` via the pairing model) are all
//! expected-O(E) per attempt, so million-node sparse topologies build in
//! seconds without ever touching an O(N²) loop.

mod builders;
mod graph;
mod properties;

pub use builders::{
    barabasi_albert, complete, erdos_renyi, grid2d, k_regular, pair, paper_four_node, path,
    random_geometric, ring, star,
};
pub use graph::Graph;
pub use properties::{degree_stats, DegreeStats};
