//! Network topologies.
//!
//! The paper's experiments run on a 2-node pair (Fig. 1), the custom
//! 4-node star-like graph of Fig. 3, and circle graphs of growing size
//! (Fig. 9/10). This module provides those plus the standard families used
//! for scaling and robustness studies (complete, path, star, 2-D grid,
//! Erdős–Rényi, Barabási–Albert scale-free — the paper's §IV-A remark about
//! scale-free node degrees motivates the last one).

mod builders;
mod graph;
mod properties;

pub use builders::{
    barabasi_albert, complete, erdos_renyi, grid2d, pair, paper_four_node, path, ring, star,
};
pub use graph::Graph;
pub use properties::{degree_stats, DegreeStats};
