//! Degree statistics — used to size the per-node neighbor memory that
//! ADC-DGD requires (paper §IV-A remark i).

use super::Graph;

/// Summary of a graph's degree distribution.
#[derive(Debug, Clone, PartialEq)]
pub struct DegreeStats {
    /// Minimum degree.
    pub min: usize,
    /// Maximum degree.
    pub max: usize,
    /// Mean degree.
    pub mean: f64,
    /// Total neighbor-memory slots = Σ_i deg(i) = 2E. Each slot stores one
    /// P-dimensional mirror vector x̃ under ADC-DGD.
    pub total_memory_slots: usize,
}

/// Compute degree statistics.
pub fn degree_stats(g: &Graph) -> DegreeStats {
    let n = g.num_nodes();
    let degs: Vec<usize> = (0..n).map(|i| g.degree(i)).collect();
    let total: usize = degs.iter().sum();
    DegreeStats {
        min: degs.iter().copied().min().unwrap_or(0),
        max: degs.iter().copied().max().unwrap_or(0),
        mean: total as f64 / n as f64,
        total_memory_slots: total,
    }
}

#[cfg(test)]
mod tests {
    use super::super::builders;
    use super::*;

    #[test]
    fn ring_stats() {
        let s = degree_stats(&builders::ring(10));
        assert_eq!(s.min, 2);
        assert_eq!(s.max, 2);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.total_memory_slots, 20);
    }

    #[test]
    fn star_stats() {
        let s = degree_stats(&builders::star(5));
        assert_eq!(s.min, 1);
        assert_eq!(s.max, 4);
        assert_eq!(s.total_memory_slots, 8); // 2E = 2*4
    }

    #[test]
    fn scale_free_memory_is_modest() {
        // The §IV-A remark: in scale-free graphs most nodes are low-degree,
        // so total mirror memory stays near 2·m·n.
        let g = builders::barabasi_albert(100, 2, 1);
        let s = degree_stats(&g);
        assert!(s.mean < 5.0, "mean={}", s.mean);
    }
}
