//! Undirected graph type used as the communication topology.

use std::collections::VecDeque;

/// An undirected graph on nodes `0..n`. Edges are stored both as a sorted
/// edge list and as adjacency lists for O(1) neighbor iteration.
#[derive(Debug, Clone)]
pub struct Graph {
    n: usize,
    edges: Vec<(usize, usize)>,
    adj: Vec<Vec<usize>>,
}

impl Graph {
    /// Build a graph from an edge list. Self-loops and duplicate edges are
    /// rejected; endpoints must be `< n`.
    pub fn new(n: usize, mut edges: Vec<(usize, usize)>) -> Self {
        assert!(n > 0, "graph must have at least one node");
        for e in edges.iter_mut() {
            assert!(e.0 < n && e.1 < n, "edge {e:?} out of range for n={n}");
            assert_ne!(e.0, e.1, "self-loop {e:?} not allowed");
            if e.0 > e.1 {
                *e = (e.1, e.0);
            }
        }
        edges.sort_unstable();
        let before = edges.len();
        edges.dedup();
        assert_eq!(before, edges.len(), "duplicate edges not allowed");
        let mut adj = vec![Vec::new(); n];
        for &(u, v) in &edges {
            adj[u].push(v);
            adj[v].push(u);
        }
        for a in adj.iter_mut() {
            a.sort_unstable();
        }
        // Sorted, deduplicated adjacency rows are a crate-wide invariant:
        // the bus's link-stats lookup binary-searches rows, the mailbox
        // plane equates slot index with row position, and the CSR mixing
        // order (ascending neighbors) is what keeps engines bit-identical.
        // Edge dedup above plus this sort guarantee it; assert loudly so
        // any future construction path cannot silently break it.
        for (i, a) in adj.iter().enumerate() {
            debug_assert!(
                a.windows(2).all(|w| w[0] < w[1]),
                "adjacency row {i} must be strictly ascending: {a:?}"
            );
        }
        Self { n, edges, adj }
    }

    /// Number of nodes `N`.
    pub fn num_nodes(&self) -> usize {
        self.n
    }

    /// Number of undirected links `E`.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Sorted edge list (u < v).
    pub fn edges(&self) -> &[(usize, usize)] {
        &self.edges
    }

    /// Neighbors of node `i` (sorted).
    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    /// Degree of node `i`.
    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    /// Maximum degree.
    pub fn max_degree(&self) -> usize {
        self.adj.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Are `u` and `v` adjacent?
    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.adj[u].binary_search(&v).is_ok()
    }

    /// BFS connectivity check. Consensus requires a connected graph
    /// (paper §III-A assumes an undirected *connected* G).
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut q = VecDeque::new();
        q.push_back(0);
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = q.pop_front() {
            for &v in &self.adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    q.push_back(v);
                }
            }
        }
        count == self.n
    }

    /// Graph diameter via BFS from every node (∞/None if disconnected).
    pub fn diameter(&self) -> Option<usize> {
        let mut diam = 0;
        for s in 0..self.n {
            let mut dist = vec![usize::MAX; self.n];
            let mut q = VecDeque::new();
            dist[s] = 0;
            q.push_back(s);
            while let Some(u) = q.pop_front() {
                for &v in &self.adj[u] {
                    if dist[v] == usize::MAX {
                        dist[v] = dist[u] + 1;
                        q.push_back(v);
                    }
                }
            }
            let far = *dist.iter().max().unwrap();
            if far == usize::MAX {
                return None;
            }
            diam = diam.max(far);
        }
        Some(diam)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_construction() {
        let g = Graph::new(3, vec![(0, 1), (2, 1)]);
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert!(g.has_edge(1, 2));
        assert!(!g.has_edge(0, 2));
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.max_degree(), 2);
    }

    #[test]
    #[should_panic(expected = "self-loop")]
    fn rejects_self_loop() {
        let _ = Graph::new(2, vec![(1, 1)]);
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn rejects_duplicate_edges() {
        let _ = Graph::new(2, vec![(0, 1), (1, 0)]);
    }

    /// Rows must come out sorted and deduplicated no matter how unruly
    /// the edge list is — descending, flipped, interleaved. Both the
    /// bus's binary-searched stats lookup and the CSR/mailbox slot
    /// alignment silently rely on this.
    #[test]
    fn adjacency_rows_sorted_for_unsorted_edge_input() {
        let g = Graph::new(5, vec![(4, 0), (3, 0), (2, 0), (1, 0), (4, 2), (1, 3)]);
        for i in 0..5 {
            let row = g.neighbors(i);
            assert!(
                row.windows(2).all(|w| w[0] < w[1]),
                "row {i} not strictly ascending: {row:?}"
            );
        }
        assert_eq!(g.neighbors(0), &[1, 2, 3, 4]);
        assert_eq!(g.neighbors(2), &[0, 4]);
        // Binary-search-backed lookups agree with membership.
        assert!(g.has_edge(0, 4) && g.has_edge(4, 0));
        assert!(!g.has_edge(2, 3));
    }

    #[test]
    fn connectivity() {
        let connected = Graph::new(3, vec![(0, 1), (1, 2)]);
        assert!(connected.is_connected());
        let disconnected = Graph::new(4, vec![(0, 1), (2, 3)]);
        assert!(!disconnected.is_connected());
        let single = Graph::new(1, vec![]);
        assert!(single.is_connected());
    }

    #[test]
    fn diameter_values() {
        let path3 = Graph::new(3, vec![(0, 1), (1, 2)]);
        assert_eq!(path3.diameter(), Some(2));
        let disconnected = Graph::new(2, vec![]);
        assert_eq!(disconnected.diameter(), None);
        let k3 = Graph::new(3, vec![(0, 1), (1, 2), (0, 2)]);
        assert_eq!(k3.diameter(), Some(1));
    }
}
