//! Vector kernels used on the per-node hot path.
//!
//! These are deliberately written over plain slices so algorithm code can
//! reuse preallocated buffers — the steady-state round loop performs no
//! allocation (see DESIGN.md §8).

/// `y += a * x` (fused multiply-add over slices).
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * *xi;
    }
}

/// `y = a * x + b * y`.
#[inline]
pub fn axpby(a: f64, x: &[f64], b: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi = a * *xi + b * *yi;
    }
}

/// Dot product.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y.iter()).map(|(a, b)| a * b).sum()
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// Reassociated four-lane dot product — the **`fast` profile**.
///
/// [`dot`] reduces strictly left-to-right, which pins its bits but
/// serializes the FP dependency chain. This variant accumulates four
/// interleaved partial sums (so the adds pipeline/autovectorize) and
/// folds them pairwise at the end. Results differ from [`dot`] only by
/// reassociation roundoff (≤ a few ulps relative), so it is **opt-in**:
/// used where a tolerance already governs the answer (β power iteration,
/// bench-side norms), never in data-plane kernels whose outputs are
/// golden-bit-pinned across engines.
#[inline]
pub fn dot_fast(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    const LANES: usize = 4;
    let mut acc = [0.0f64; LANES];
    let xc = x.chunks_exact(LANES);
    let yc = y.chunks_exact(LANES);
    let (xr, yr) = (xc.remainder(), yc.remainder());
    for (xs, ys) in xc.zip(yc) {
        for (a, (xi, yi)) in acc.iter_mut().zip(xs.iter().zip(ys)) {
            *a += xi * yi;
        }
    }
    let mut tail = 0.0;
    for (xi, yi) in xr.iter().zip(yr) {
        tail += xi * yi;
    }
    (acc[0] + acc[2]) + (acc[1] + acc[3]) + tail
}

/// Euclidean norm via [`dot_fast`] — same `fast`-profile caveats apply.
#[inline]
pub fn norm2_fast(x: &[f64]) -> f64 {
    dot_fast(x, x).sqrt()
}

/// Squared Euclidean norm.
#[inline]
pub fn norm2_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// Infinity norm (max absolute value); 0 for empty input.
#[inline]
pub fn norm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0f64, |m, v| m.max(v.abs()))
}

/// Euclidean distance between two vectors.
#[inline]
pub fn dist2(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y.iter())
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt()
}

/// `out = x - y`.
#[inline]
pub fn sub(x: &[f64], y: &[f64], out: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    for ((o, a), b) in out.iter_mut().zip(x.iter()).zip(y.iter()) {
        *o = a - b;
    }
}

/// Scale in place: `x *= a`.
#[inline]
pub fn scale(x: &mut [f64], a: f64) {
    for v in x.iter_mut() {
        *v *= a;
    }
}

/// `out = a * x` (fused copy + scale over row views; replaces the
/// `copy_from_slice` + [`scale`] pair bit-for-bit — IEEE multiplication
/// is commutative).
#[inline]
pub fn scale_into(a: f64, x: &[f64], out: &mut [f64]) {
    debug_assert_eq!(x.len(), out.len());
    for (o, xi) in out.iter_mut().zip(x.iter()) {
        *o = a * *xi;
    }
}

/// `out = base + a * x` — the plane-backed gradient-step kernel.
/// Element-wise it performs `base[e] + (a * x[e])`, exactly the rounding
/// sequence of the historical swap-then-[`axpy`] update.
#[inline]
pub fn add_scaled(base: &[f64], a: f64, x: &[f64], out: &mut [f64]) {
    debug_assert_eq!(base.len(), out.len());
    debug_assert_eq!(x.len(), out.len());
    for ((o, b), xi) in out.iter_mut().zip(base.iter()).zip(x.iter()) {
        *o = *b + a * *xi;
    }
}

/// `out = a * (x − y)` — the fused amplified-differential kernel
/// (ADC-DGD's `k^γ (x_k − x̃_{k−1})`) over row views.
#[inline]
pub fn scaled_diff(a: f64, x: &[f64], y: &[f64], out: &mut [f64]) {
    debug_assert_eq!(x.len(), out.len());
    debug_assert_eq!(y.len(), out.len());
    for ((o, xi), yi) in out.iter_mut().zip(x.iter()).zip(y.iter()) {
        *o = a * (*xi - *yi);
    }
}

/// Row `i` of a row-major `· × p` arena.
#[inline]
pub fn row(buf: &[f64], p: usize, i: usize) -> &[f64] {
    &buf[i * p..(i + 1) * p]
}

/// Mutable row `i` of a row-major `· × p` arena.
#[inline]
pub fn row_mut(buf: &mut [f64], p: usize, i: usize) -> &mut [f64] {
    &mut buf[i * p..(i + 1) * p]
}

/// Set all entries to `v`.
#[inline]
pub fn fill(x: &mut [f64], v: f64) {
    for e in x.iter_mut() {
        *e = v;
    }
}

/// Arithmetic mean of a slice (0 for empty input).
#[inline]
pub fn mean(x: &[f64]) -> f64 {
    if x.is_empty() {
        0.0
    } else {
        x.iter().sum::<f64>() / x.len() as f64
    }
}

/// Column-wise mean of `n` stacked vectors of length `p` (row-major).
/// Returns the mean vector `x̄ = (1/n) Σ x_i` — the consensus target of
/// paper Theorem 1.
pub fn stacked_mean(rows: &[Vec<f64>]) -> Vec<f64> {
    assert!(!rows.is_empty());
    let p = rows[0].len();
    let mut out = vec![0.0; p];
    for r in rows {
        assert_eq!(r.len(), p, "ragged stack");
        axpy(1.0, r, &mut out);
    }
    scale(&mut out, 1.0 / rows.len() as f64);
    out
}

/// Consensus error `‖x − x̄‖₂` of stacked local copies (paper Thm 1's
/// left-hand side): sqrt of Σ_i ‖x_i − x̄‖².
pub fn consensus_error(rows: &[Vec<f64>]) -> f64 {
    let xbar = stacked_mean(rows);
    rows.iter()
        .map(|r| {
            r.iter()
                .zip(xbar.iter())
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
        })
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn axpby_basic() {
        let x = [1.0, 2.0];
        let mut y = [3.0, 4.0];
        axpby(2.0, &x, 0.5, &mut y);
        assert_eq!(y, [3.5, 6.0]);
    }

    #[test]
    fn norms() {
        let x = [3.0, -4.0];
        assert!((norm2(&x) - 5.0).abs() < 1e-12);
        assert_eq!(norm_inf(&x), 4.0);
        assert_eq!(norm2_sq(&x), 25.0);
        assert_eq!(norm_inf(&[]), 0.0);
    }

    #[test]
    fn dist_and_sub() {
        let x = [1.0, 2.0];
        let y = [4.0, 6.0];
        assert!((dist2(&x, &y) - 5.0).abs() < 1e-12);
        let mut out = [0.0; 2];
        sub(&x, &y, &mut out);
        assert_eq!(out, [-3.0, -4.0]);
    }

    #[test]
    fn stacked_mean_and_consensus_error() {
        let rows = vec![vec![1.0, 0.0], vec![3.0, 4.0]];
        let m = stacked_mean(&rows);
        assert_eq!(m, vec![2.0, 2.0]);
        // deviations: (−1,−2) and (1,2): total sq = 1+4+1+4 = 10
        assert!((consensus_error(&rows) - 10f64.sqrt()).abs() < 1e-12);
        // Identical rows have zero consensus error.
        let same = vec![vec![5.0, 6.0]; 4];
        assert_eq!(consensus_error(&same), 0.0);
    }

    /// The fast profile is allowed to reassociate but must stay within
    /// accumulated-roundoff distance of the sequential reduction on every
    /// length (lane-multiple, ragged, short, empty).
    #[test]
    fn dot_fast_agrees_with_sequential_within_roundoff() {
        for len in [0usize, 1, 3, 4, 7, 8, 33, 1000] {
            let x: Vec<f64> = (0..len).map(|i| (i as f64 * 0.619).sin()).collect();
            let y: Vec<f64> = (0..len).map(|i| (i as f64 * 0.271).cos()).collect();
            let exact = dot(&x, &y);
            let fast = dot_fast(&x, &y);
            let scale = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum::<f64>().max(1.0);
            assert!(
                (exact - fast).abs() <= 1e-14 * scale,
                "len={len}: {exact} vs {fast}"
            );
            assert!((norm2(&x) - norm2_fast(&x)).abs() <= 1e-12 * norm2(&x).max(1.0));
        }
    }

    #[test]
    fn mean_handles_empty() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
    }

    #[test]
    fn scale_into_matches_copy_then_scale() {
        let x = [1.5, -2.0, 0.25];
        let mut fused = [0.0; 3];
        scale_into(0.3, &x, &mut fused);
        let mut reference = x;
        scale(&mut reference, 0.3);
        assert_eq!(fused, reference);
    }

    #[test]
    fn add_scaled_matches_swap_then_axpy() {
        let base = [1.0, 2.0, 3.0];
        let g = [0.5, -0.25, 4.0];
        let mut fused = [0.0; 3];
        add_scaled(&base, -0.1, &g, &mut fused);
        let mut reference = base;
        axpy(-0.1, &g, &mut reference);
        assert_eq!(fused, reference);
    }

    #[test]
    fn scaled_diff_is_elementwise() {
        let x = [3.0, 1.0];
        let y = [1.0, 4.0];
        let mut out = [0.0; 2];
        scaled_diff(2.0, &x, &y, &mut out);
        assert_eq!(out, [4.0, -6.0]);
    }

    #[test]
    fn row_views_index_row_major() {
        let mut buf = vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(row(&buf, 2, 1), &[2.0, 3.0]);
        row_mut(&mut buf, 3, 1)[0] = 9.0;
        assert_eq!(buf[3], 9.0);
    }
}
