//! Spectral utilities: power iteration and the consensus spectral gap.
//!
//! For a consensus matrix `W` (symmetric, doubly stochastic), convergence
//! of DGD-type methods is governed by `β = max(|λ₂(W)|, |λ_N(W)|)` — the
//! second-largest eigenvalue *magnitude* (paper §III-A). Since `W`'s top
//! eigenpair is known exactly (`λ₁ = 1`, eigenvector `1/√N`), we compute β
//! by power iteration on the deflated matrix `W − (1/N)·11ᵀ`.

use super::vecops;
use super::Matrix;
use crate::rng::Xoshiro256pp;

/// Result of a power iteration run.
#[derive(Debug, Clone)]
pub struct PowerIterationResult {
    /// Dominant eigenvalue estimate (by magnitude; sign recovered via the
    /// Rayleigh quotient).
    pub eigenvalue: f64,
    /// Corresponding unit eigenvector estimate.
    pub eigenvector: Vec<f64>,
    /// Iterations actually performed.
    pub iterations: usize,
    /// Final residual `‖A v − λ v‖`.
    pub residual: f64,
}

/// Power iteration for the dominant (largest |λ|) eigenpair of a square
/// matrix `a`. Deterministic given `seed`.
pub fn power_iteration(a: &Matrix, max_iter: usize, tol: f64, seed: u64) -> PowerIterationResult {
    assert_eq!(a.rows(), a.cols(), "power iteration requires a square matrix");
    let n = a.rows();
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut v: Vec<f64> = (0..n).map(|_| rng.next_f64() - 0.5).collect();
    let nrm = vecops::norm2(&v).max(f64::MIN_POSITIVE);
    vecops::scale(&mut v, 1.0 / nrm);

    let mut av = vec![0.0; n];
    let mut lambda = 0.0;
    let mut iterations = 0;
    let mut residual = f64::INFINITY;
    for it in 0..max_iter {
        iterations = it + 1;
        a.matvec_into(&v, &mut av);
        // Rayleigh quotient gives a signed eigenvalue estimate.
        lambda = vecops::dot(&v, &av);
        // residual = ‖Av − λv‖
        residual = av
            .iter()
            .zip(v.iter())
            .map(|(x, y)| (x - lambda * y) * (x - lambda * y))
            .sum::<f64>()
            .sqrt();
        let nrm = vecops::norm2(&av);
        if nrm < f64::MIN_POSITIVE {
            // a v = 0: v is in the kernel; eigenvalue 0.
            lambda = 0.0;
            break;
        }
        for (vi, avi) in v.iter_mut().zip(av.iter()) {
            *vi = avi / nrm;
        }
        if residual < tol {
            break;
        }
    }
    PowerIterationResult { eigenvalue: lambda, eigenvector: v, iterations, residual }
}

/// Estimate `β = max(|λ₂(W)|, |λ_N(W)|)` of a doubly-stochastic symmetric
/// consensus matrix by deflating the known top eigenpair (`λ₁ = 1`,
/// `v₁ = 1/√N`) and running power iteration on the remainder.
pub fn estimate_beta(w: &Matrix) -> f64 {
    assert_eq!(w.rows(), w.cols());
    let n = w.rows();
    if n == 1 {
        return 0.0;
    }
    // Deflate: B = W − (1/N) 1 1ᵀ. The spectrum of B is that of W with the
    // eigenvalue 1 (eigenvector 1) replaced by 0, so |λ|max(B) = β.
    let mut b = w.clone();
    let c = 1.0 / n as f64;
    for i in 0..n {
        for j in 0..n {
            b[(i, j)] -= c;
        }
    }
    let res = power_iteration(&b, 10_000, 1e-13, 0xBEEF);
    res.eigenvalue.abs()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_iteration_diagonal() {
        let a = Matrix::from_rows(&[vec![3.0, 0.0], vec![0.0, 1.0]]);
        let r = power_iteration(&a, 1000, 1e-12, 1);
        assert!((r.eigenvalue - 3.0).abs() < 1e-9, "λ={}", r.eigenvalue);
        assert!(r.eigenvector[0].abs() > 0.99);
    }

    #[test]
    fn power_iteration_negative_dominant() {
        let a = Matrix::from_rows(&[vec![-5.0, 0.0], vec![0.0, 2.0]]);
        let r = power_iteration(&a, 2000, 1e-12, 2);
        assert!((r.eigenvalue + 5.0).abs() < 1e-8, "λ={}", r.eigenvalue);
    }

    #[test]
    fn beta_of_complete_average_is_zero() {
        // W = (1/N) 11ᵀ has spectrum {1, 0, ..., 0} ⇒ β = 0.
        let n = 4;
        let w = Matrix::from_vec(n, n, vec![1.0 / n as f64; n * n]);
        assert!(estimate_beta(&w) < 1e-9);
    }

    #[test]
    fn beta_of_identity_is_one() {
        // W = I: every eigenvalue is 1 ⇒ deflated spectrum still has 1.
        let w = Matrix::identity(3);
        assert!((estimate_beta(&w) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn beta_of_two_node_metropolis() {
        // W = [[1/2, 1/2], [1/2, 1/2]] ⇒ eigenvalues {1, 0} ⇒ β = 0.
        let w = Matrix::from_rows(&[vec![0.5, 0.5], vec![0.5, 0.5]]);
        assert!(estimate_beta(&w) < 1e-9);
    }

    #[test]
    fn beta_of_paper_four_node_matrix() {
        // Paper Fig. 4's W: eigenvalues are {1, 3/4, 3/4, −1/4} ⇒ β = 3/4.
        let w = Matrix::from_rows(&[
            vec![0.25, 0.25, 0.25, 0.25],
            vec![0.25, 0.75, 0.0, 0.0],
            vec![0.25, 0.0, 0.75, 0.0],
            vec![0.25, 0.0, 0.0, 0.75],
        ]);
        let beta = estimate_beta(&w);
        assert!((beta - 0.75).abs() < 1e-6, "beta={beta}");
    }
}
