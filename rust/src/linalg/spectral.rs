//! Spectral utilities: power iteration and the consensus spectral gap.
//!
//! For a consensus matrix `W` (symmetric, doubly stochastic), convergence
//! of DGD-type methods is governed by `β = max(|λ₂(W)|, |λ_N(W)|)` — the
//! second-largest eigenvalue *magnitude* (paper §III-A). Since `W`'s top
//! eigenpair is known exactly (`λ₁ = 1`, eigenvector `1/√N`), we compute β
//! by power iteration on the deflated operator `B = W − (1/N)·11ᵀ`.
//!
//! Two subtleties drive the implementation shape:
//!
//! - **±β spectra.** When `λ₂ = −λ_N` in magnitude (max-degree weights on
//!   bipartite graphs, e.g. even rings), plain power iteration on `B`
//!   oscillates between the two eigenvectors and its Rayleigh quotient
//!   can settle anywhere in `[−β, β]`. Both β estimators therefore
//!   iterate the *squared* operator (two applies per step): `B²` is PSD
//!   with top eigenvalue `β²`, so the ± ambiguity vanishes and
//!   `β = √λ_max(B²)`.
//! - **Scale.** [`estimate_beta`] deflates a dense clone (fine at small
//!   `N`); [`estimate_beta_csr`] applies the deflation *implicitly* —
//!   `B v = W v − mean(v)·1` via one CSR matvec — so β at `N ≫ 10⁴`
//!   costs O(E) per step and never materializes an `N × N` structure.

use super::vecops;
use super::Matrix;
use crate::consensus::CsrWeights;
use crate::rng::Xoshiro256pp;

/// Result of a power iteration run.
#[derive(Debug, Clone)]
pub struct PowerIterationResult {
    /// Dominant eigenvalue estimate (by magnitude; sign recovered via the
    /// Rayleigh quotient).
    pub eigenvalue: f64,
    /// Corresponding unit eigenvector estimate.
    pub eigenvector: Vec<f64>,
    /// Iterations actually performed.
    pub iterations: usize,
    /// Final residual `‖A v − λ v‖`.
    pub residual: f64,
}

/// Power iteration for the dominant (largest |λ|) eigenpair of a square
/// matrix `a`. Deterministic given `seed`.
pub fn power_iteration(a: &Matrix, max_iter: usize, tol: f64, seed: u64) -> PowerIterationResult {
    assert_eq!(a.rows(), a.cols(), "power iteration requires a square matrix");
    let n = a.rows();
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut v: Vec<f64> = (0..n).map(|_| rng.next_f64() - 0.5).collect();
    let nrm = vecops::norm2(&v).max(f64::MIN_POSITIVE);
    vecops::scale(&mut v, 1.0 / nrm);

    let mut av = vec![0.0; n];
    let mut lambda = 0.0;
    let mut iterations = 0;
    let mut residual = f64::INFINITY;
    for it in 0..max_iter {
        iterations = it + 1;
        a.matvec_into(&v, &mut av);
        // Rayleigh quotient gives a signed eigenvalue estimate.
        lambda = vecops::dot(&v, &av);
        // residual = ‖Av − λv‖
        residual = av
            .iter()
            .zip(v.iter())
            .map(|(x, y)| (x - lambda * y) * (x - lambda * y))
            .sum::<f64>()
            .sqrt();
        let nrm = vecops::norm2(&av);
        if nrm < f64::MIN_POSITIVE {
            // a v = 0: v is in the kernel; eigenvalue 0.
            lambda = 0.0;
            break;
        }
        for (vi, avi) in v.iter_mut().zip(av.iter()) {
            *vi = avi / nrm;
        }
        if residual < tol {
            break;
        }
    }
    PowerIterationResult { eigenvalue: lambda, eigenvector: v, iterations, residual }
}

/// Power iteration on the *square* of a symmetric operator supplied as an
/// `apply` closure: returns `√max(λ_max(B²), 0)`. Squaring makes the
/// operator PSD, which is what rescues ±β spectra (see module docs) —
/// and since both β estimators route through this one driver with the
/// same seed, start vector, and stopping rule, their estimates agree to
/// far better than the 1e-9 the property suite pins.
///
/// Uses the reassociated `fast`-profile reductions ([`vecops::dot_fast`]/
/// [`vecops::norm2_fast`]): β estimation is an iterative solve with its
/// own tolerance, not a bit-pinned data-plane kernel.
fn beta_via_squared_op(
    n: usize,
    mut apply: impl FnMut(&[f64], &mut [f64]),
    max_iter: usize,
    tol: f64,
    seed: u64,
) -> f64 {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut v: Vec<f64> = (0..n).map(|_| rng.next_f64() - 0.5).collect();
    let nrm = vecops::norm2_fast(&v).max(f64::MIN_POSITIVE);
    vecops::scale(&mut v, 1.0 / nrm);

    let mut bv = vec![0.0; n];
    let mut bbv = vec![0.0; n];
    let mut lambda: f64 = 0.0;
    for _ in 0..max_iter {
        apply(&v, &mut bv);
        apply(&bv, &mut bbv);
        // Rayleigh quotient of B² (≥ 0 up to roundoff: it is ‖Bv‖²).
        lambda = vecops::dot_fast(&v, &bbv);
        let residual = bbv
            .iter()
            .zip(v.iter())
            .map(|(x, y)| (x - lambda * y) * (x - lambda * y))
            .sum::<f64>()
            .sqrt();
        let nrm = vecops::norm2_fast(&bbv);
        if nrm < f64::MIN_POSITIVE {
            // B² v = 0: v is in the kernel; eigenvalue 0.
            lambda = 0.0;
            break;
        }
        for (vi, bbvi) in v.iter_mut().zip(bbv.iter()) {
            *vi = bbvi / nrm;
        }
        if residual < tol {
            break;
        }
    }
    lambda.max(0.0).sqrt()
}

/// Estimate `β = max(|λ₂(W)|, |λ_N(W)|)` of a doubly-stochastic symmetric
/// consensus matrix by deflating the known top eigenpair (`λ₁ = 1`,
/// `v₁ = 1/√N`) and power-iterating the squared remainder.
pub fn estimate_beta(w: &Matrix) -> f64 {
    assert_eq!(w.rows(), w.cols());
    let n = w.rows();
    if n == 1 {
        return 0.0;
    }
    // Deflate: B = W − (1/N) 1 1ᵀ. The spectrum of B is that of W with the
    // eigenvalue 1 (eigenvector 1) replaced by 0, so |λ|max(B) = β.
    let mut b = w.clone();
    let c = 1.0 / n as f64;
    for i in 0..n {
        for j in 0..n {
            b[(i, j)] -= c;
        }
    }
    beta_via_squared_op(n, |v, out| b.matvec_into(v, out), 10_000, 1e-13, 0xBEEF)
}

/// Sparse `β` for CSR consensus weights, with the deflation applied
/// *implicitly*: `B v = W v − mean(v)·1` costs one O(E) CSR matvec plus
/// an O(N) sweep, so no dense `N × N` clone ever exists. Same squared
/// iteration, seed, and stopping rule as [`estimate_beta`], so the two
/// agree to well under 1e-9 on matched inputs (property-pinned).
pub fn estimate_beta_csr(w: &CsrWeights) -> f64 {
    let n = w.n();
    if n == 1 {
        return 0.0;
    }
    let apply = |v: &[f64], out: &mut [f64]| {
        w.matvec_into(v, out);
        let m = vecops::mean(v);
        for o in out.iter_mut() {
            *o -= m;
        }
    };
    beta_via_squared_op(n, apply, 10_000, 1e-13, 0xBEEF)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_iteration_diagonal() {
        let a = Matrix::from_rows(&[vec![3.0, 0.0], vec![0.0, 1.0]]);
        let r = power_iteration(&a, 1000, 1e-12, 1);
        assert!((r.eigenvalue - 3.0).abs() < 1e-9, "λ={}", r.eigenvalue);
        assert!(r.eigenvector[0].abs() > 0.99);
    }

    #[test]
    fn power_iteration_negative_dominant() {
        let a = Matrix::from_rows(&[vec![-5.0, 0.0], vec![0.0, 2.0]]);
        let r = power_iteration(&a, 2000, 1e-12, 2);
        assert!((r.eigenvalue + 5.0).abs() < 1e-8, "λ={}", r.eigenvalue);
    }

    #[test]
    fn beta_of_complete_average_is_zero() {
        // W = (1/N) 11ᵀ has spectrum {1, 0, ..., 0} ⇒ β = 0.
        let n = 4;
        let w = Matrix::from_vec(n, n, vec![1.0 / n as f64; n * n]);
        assert!(estimate_beta(&w) < 1e-9);
    }

    #[test]
    fn beta_of_identity_is_one() {
        // W = I: every eigenvalue is 1 ⇒ deflated spectrum still has 1.
        let w = Matrix::identity(3);
        assert!((estimate_beta(&w) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn beta_of_two_node_metropolis() {
        // W = [[1/2, 1/2], [1/2, 1/2]] ⇒ eigenvalues {1, 0} ⇒ β = 0.
        let w = Matrix::from_rows(&[vec![0.5, 0.5], vec![0.5, 0.5]]);
        assert!(estimate_beta(&w) < 1e-9);
    }

    #[test]
    fn beta_of_paper_four_node_matrix() {
        // Paper Fig. 4's W: eigenvalues are {1, 3/4, 3/4, −1/4} ⇒ β = 3/4.
        let w = Matrix::from_rows(&[
            vec![0.25, 0.25, 0.25, 0.25],
            vec![0.25, 0.75, 0.0, 0.0],
            vec![0.25, 0.0, 0.75, 0.0],
            vec![0.25, 0.0, 0.0, 0.75],
        ]);
        let beta = estimate_beta(&w);
        assert!((beta - 0.75).abs() < 1e-6, "beta={beta}");
    }

    /// Regression for the ±β oscillation: max-degree weights on an even
    /// ring (bipartite) have spectrum `{1, 1/3, 1/3, −1/3}` on C₄ —
    /// `|λ₂| = |λ_N| = 1/3` with opposite signs. Plain power iteration on
    /// the deflated matrix bounces between the two eigenvectors and its
    /// Rayleigh quotient never settles; the squared iteration sees the
    /// PSD `B²` with top eigenvalue `1/9` and converges cleanly.
    #[test]
    fn beta_handles_bipartite_plus_minus_spectrum() {
        // Max-degree on C₄ (Δ = 2 ⇒ link weight 1/3, diagonal 1/3): the
        // circulant [1/3, 1/3, 0, 1/3] has eigenvalues 1/3 + (2/3)cos(πk/2).
        let third = 1.0 / 3.0;
        let w = Matrix::from_rows(&[
            vec![third, third, 0.0, third],
            vec![third, third, third, 0.0],
            vec![0.0, third, third, third],
            vec![third, 0.0, third, third],
        ]);
        let beta = estimate_beta(&w);
        assert!((beta - third).abs() < 1e-9, "beta={beta}");
        // Sparse pathway agrees on the same operator.
        let g = crate::topology::ring(4);
        let csr = crate::consensus::max_degree_csr(&g);
        let sparse = estimate_beta_csr(&csr);
        assert!((sparse - third).abs() < 1e-9, "sparse beta={sparse}");
    }

    #[test]
    fn sparse_beta_matches_dense_on_paper_matrix() {
        let (g, cm) = crate::consensus::paper_four_node_w();
        let csr = CsrWeights::from_consensus(&cm, &g);
        let sparse = estimate_beta_csr(&csr);
        assert!((sparse - cm.beta()).abs() < 1e-9, "sparse={sparse} dense={}", cm.beta());
        assert!((sparse - 0.75).abs() < 1e-6);
    }

    #[test]
    fn sparse_beta_single_node_is_zero() {
        let csr = CsrWeights::from_parts(vec![1.0], vec![0, 0], vec![], vec![]);
        assert_eq!(estimate_beta_csr(&csr), 0.0);
    }
}
