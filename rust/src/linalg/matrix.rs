//! Small dense row-major matrix used for consensus matrices `W` and
//! spectral diagnostics. `N` (number of nodes) is small, so simplicity and
//! correctness beat asymptotics here.

use std::fmt;

/// Dense row-major `rows × cols` matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major flat vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    /// Build from nested rows.
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        assert!(!rows.is_empty());
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        Self { rows: rows.len(), cols, data }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow the flat row-major data.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Borrow one row.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// `y = A x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// `y = A x` into a preallocated buffer (hot-path variant).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for (i, yi) in y.iter_mut().enumerate() {
            *yi = super::vecops::dot(self.row(i), x);
        }
    }

    /// Matrix product `A B`.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "inner dims mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += aik * other[(k, j)];
                }
            }
        }
        out
    }

    /// Matrix power `A^k` (binary exponentiation). Requires square `A`.
    pub fn pow(&self, mut k: u32) -> Matrix {
        assert_eq!(self.rows, self.cols, "pow requires square matrix");
        let mut result = Matrix::identity(self.rows);
        let mut base = self.clone();
        while k > 0 {
            if k & 1 == 1 {
                result = result.matmul(&base);
            }
            base = base.matmul(&base);
            k >>= 1;
        }
        result
    }

    /// Transpose.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Max absolute entry-wise difference to another matrix.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        self.data
            .iter()
            .zip(other.data.iter())
            .fold(0.0f64, |m, (a, b)| m.max((a - b).abs()))
    }

    /// Is this matrix symmetric (within `tol`)?
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Row sums.
    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.rows).map(|i| self.row(i).iter().sum()).collect()
    }

    /// Column sums.
    pub fn col_sums(&self) -> Vec<f64> {
        let mut s = vec![0.0; self.cols];
        for i in 0..self.rows {
            for (j, sj) in s.iter_mut().enumerate() {
                *sj += self[(i, j)];
            }
        }
        s
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows {
            writeln!(f, "  {:?}", self.row(i))?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matvec() {
        let i3 = Matrix::identity(3);
        let x = vec![1.0, 2.0, 3.0];
        assert_eq!(i3.matvec(&x), x);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Matrix::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn pow_matches_repeated_mul() {
        let a = Matrix::from_rows(&[vec![0.5, 0.5], vec![0.5, 0.5]]);
        let a5 = a.pow(5);
        let mut ref_m = Matrix::identity(2);
        for _ in 0..5 {
            ref_m = ref_m.matmul(&a);
        }
        assert!(a5.max_abs_diff(&ref_m) < 1e-12);
        // Doubly-stochastic rank-1 projector is idempotent.
        assert!(a5.max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn pow_zero_is_identity() {
        let a = Matrix::from_rows(&[vec![0.3, 0.7], vec![0.7, 0.3]]);
        assert!(a.pow(0).max_abs_diff(&Matrix::identity(2)) < 1e-15);
    }

    #[test]
    fn transpose_and_symmetry() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]);
        assert!(a.is_symmetric(0.0));
        let b = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 1.0]]);
        assert!(!b.is_symmetric(1e-9));
        assert_eq!(b.transpose().data(), &[1.0, 3.0, 2.0, 1.0]);
    }

    #[test]
    fn row_col_sums() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.row_sums(), vec![3.0, 7.0]);
        assert_eq!(a.col_sums(), vec![4.0, 6.0]);
    }

    #[test]
    fn matvec_into_reuses_buffer() {
        let a = Matrix::from_rows(&[vec![1.0, 0.0], vec![0.0, 2.0]]);
        let mut y = vec![9.0, 9.0];
        a.matvec_into(&[3.0, 4.0], &mut y);
        assert_eq!(y, vec![3.0, 8.0]);
    }
}
