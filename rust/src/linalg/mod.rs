//! Dense linear algebra for consensus computations.
//!
//! The consensus matrices in this library are small (`N × N`, with `N` the
//! number of network nodes — tens to a few hundreds), while the
//! optimization variables can be large (`P` up to millions). We therefore
//! only need:
//!
//! * a small dense row-major [`Matrix`] with matvec / matmul / powers,
//! * vector kernels (`axpy`, `dot`, norms) over `&[f64]` used by the
//!   per-node hot path,
//! * power iteration to estimate `β = max(|λ₂|, |λ_N|)` — the spectral
//!   quantity governing DGD/ADC-DGD convergence (paper §III-A) — in a
//!   dense flavor ([`estimate_beta`]) and an O(E) implicitly-deflated
//!   sparse flavor ([`estimate_beta_csr`]) for production-scale graphs.

mod matrix;
mod spectral;
pub mod vecops;

pub use matrix::Matrix;
pub use spectral::{estimate_beta, estimate_beta_csr, power_iteration, PowerIterationResult};
