//! The state plane: arena-backed storage for every per-node vector.
//!
//! Before this layer existed each node owned scattered heap vectors
//! (`x`, `grad`, scratch, plus `O(deg·P)` mirror vectors for ADC-DGD),
//! so the fleet-wide round loop was pointer-chasing and cache-hostile.
//! A [`StatePlane`] instead owns all per-node state as contiguous
//! row-major matrices:
//!
//! * `x` — the iterates, an `n × p` matrix (row `i` is node `i`'s `x_i`),
//! * `grad` — gradient rows, `n × p`,
//! * `scratch` — the mixing/amplification workspace, `n × p`,
//! * `mirror_self` — each node's own mirror `x̃_i` (`n × p`, mirror
//!   layouts only),
//! * `mirrors` — per-receiver neighbor mirrors, a ragged CSR-style arena
//!   of `Σ_i deg(i)` rows indexed by the neighbor-offset table
//!   (mirror layouts only). Mirrors stay *per receiver* because message
//!   loss makes each receiver's view of a neighbor diverge,
//! * `aux` — one extra persistent row per node (`n × p`, aux layouts
//!   only) for algorithms that carry a second state vector across
//!   rounds (CEDAS keeps its exact-diffusion `ψ` history here).
//!
//! ## Row-view borrowing rules
//!
//! Algorithms never own vectors; they borrow a [`NodeRows`] view of one
//! node's rows for the duration of a single `make_message`/`consume`
//! call. The engines hand out views so that aliasing is impossible:
//!
//! 1. The sequential engine borrows the whole plane mutably and creates
//!    one short-lived [`NodeRows`] at a time ([`StatePlane::rows`]).
//! 2. The parallel engines split the plane into disjoint contiguous
//!    [`PlaneShard`]s at node-range boundaries ([`StatePlane::shards`]);
//!    each worker owns its shard exclusively and creates views for its
//!    own nodes only ([`PlaneShard::rows`]). Shards are plain disjoint
//!    `&mut` slices, so the split is safe and zero-copy.
//! 3. Observers read iterates through shared accessors
//!    ([`StatePlane::x_row`], [`PlaneShard::x_row`]) strictly between
//!    phases, never while a view is live.
//! 4. The dimension-tiled engine schedules `(node, tile)` work units, so
//!    two workers may touch *the same node's* rows concurrently — in
//!    disjoint column ranges. Plain `&mut` splits cannot express that
//!    (rows interleave across arenas), so [`StatePlane::node_columns`]
//!    hands out raw-pointer [`NodeColumns`] handles whose unsafe
//!    accessors materialize short-lived column sub-views; the engine's
//!    phase barriers guarantee every live view is disjoint.
//!
//! The consensus mixing step over this layout is a row-parallel sparse
//! (CSR) × dense product — see [`crate::consensus::CsrWeights`].

use crate::linalg::vecops;

/// 8-aligned contiguous column-tile boundaries for dimension `p` split
/// into at most `tiles` tiles: `[0, step, 2·step, …, p]` with
/// `step = ⌈⌈p/tiles⌉/8⌉·8`. Every interior boundary is a multiple of 8
/// so (a) tiles line up with the 8-wide chunked kernels
/// ([`crate::consensus::CsrWeights::mix_row_into`], the QSGD rounding
/// blocks) and (b) a ternary tile's 2-bit codes occupy whole bytes of
/// the 4-codes-per-byte packing, letting tile workers write disjoint
/// byte ranges of one shared arena. Small `p` simply yields fewer tiles
/// than requested (degenerating to `[0, p]`), never an empty tile.
pub fn tile_bounds(p: usize, tiles: usize) -> Vec<usize> {
    assert!(p > 0 && tiles > 0, "tile_bounds needs p > 0 and tiles > 0");
    let step = p.div_ceil(tiles).div_ceil(8) * 8;
    let mut bounds = vec![0usize];
    let mut e = step;
    while e < p {
        bounds.push(e);
        e += step;
    }
    bounds.push(p);
    bounds
}

/// Shape of a [`StatePlane`]: node count, dimension, and (for mirror
/// algorithms like ADC-DGD) the per-node neighbor-mirror counts.
#[derive(Debug, Clone)]
pub struct PlaneLayout {
    n: usize,
    p: usize,
    mirror_counts: Option<Vec<usize>>,
    aux: bool,
}

impl PlaneLayout {
    /// Layout with the three dense `n × p` arenas and no mirrors.
    pub fn dense(n: usize, p: usize) -> Self {
        assert!(n > 0 && p > 0, "plane must be non-empty");
        Self { n, p, mirror_counts: None, aux: false }
    }

    /// Layout that additionally allocates `mirror_self` plus
    /// `counts[i]` neighbor-mirror rows for node `i`.
    pub fn with_mirrors(n: usize, p: usize, counts: Vec<usize>) -> Self {
        assert!(n > 0 && p > 0, "plane must be non-empty");
        assert_eq!(counts.len(), n, "one mirror count per node");
        Self { n, p, mirror_counts: Some(counts), aux: false }
    }

    /// Additionally allocate the `aux` arena (one persistent extra row
    /// per node).
    pub fn with_aux(mut self) -> Self {
        self.aux = true;
        self
    }

    /// Node count.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Per-node vector dimension.
    pub fn p(&self) -> usize {
        self.p
    }
}

/// The arena owning all per-node vectors of one run as contiguous
/// row-major matrices. See the module docs for the borrowing rules.
#[derive(Debug)]
pub struct StatePlane {
    n: usize,
    p: usize,
    x: Vec<f64>,
    grad: Vec<f64>,
    scratch: Vec<f64>,
    mirror_self: Vec<f64>,
    mirrors: Vec<f64>,
    aux: Vec<f64>,
    /// Prefix sums of per-node mirror counts (`n + 1` entries; all zero
    /// for mirror-free layouts).
    mirror_off: Vec<usize>,
}

impl StatePlane {
    /// Allocate a zero-initialized plane for `layout`.
    pub fn new(layout: &PlaneLayout) -> Self {
        let (n, p) = (layout.n, layout.p);
        let mut mirror_off = vec![0usize; n + 1];
        let (mirror_self, mirrors) = match &layout.mirror_counts {
            Some(counts) => {
                for (i, c) in counts.iter().enumerate() {
                    mirror_off[i + 1] = mirror_off[i] + c;
                }
                (vec![0.0; n * p], vec![0.0; mirror_off[n] * p])
            }
            None => (Vec::new(), Vec::new()),
        };
        Self {
            n,
            p,
            x: vec![0.0; n * p],
            grad: vec![0.0; n * p],
            scratch: vec![0.0; n * p],
            mirror_self,
            mirrors,
            aux: if layout.aux { vec![0.0; n * p] } else { Vec::new() },
            mirror_off,
        }
    }

    /// Node count.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Per-node vector dimension.
    pub fn p(&self) -> usize {
        self.p
    }

    /// Does this plane carry mirror arenas?
    pub fn has_mirrors(&self) -> bool {
        !self.mirror_self.is_empty()
    }

    /// Does this plane carry the auxiliary arena?
    pub fn has_aux(&self) -> bool {
        !self.aux.is_empty()
    }

    /// Node `i`'s auxiliary row (aux layouts only).
    #[inline]
    pub fn aux_row(&self, i: usize) -> &[f64] {
        vecops::row(&self.aux, self.p, i)
    }

    /// Copy every node's iterate row into its auxiliary row — the
    /// `ψ⁰ = x⁰` initialization convention of exact-diffusion-style
    /// algorithms, applied by the fleet builder after iterate init.
    pub fn seed_aux_from_x(&mut self) {
        assert!(self.has_aux(), "layout has no aux arena");
        self.aux.copy_from_slice(&self.x);
    }

    /// Node `i`'s iterate row.
    #[inline]
    pub fn x_row(&self, i: usize) -> &[f64] {
        vecops::row(&self.x, self.p, i)
    }

    /// Node `i`'s iterate row, mutable (initialization / tests).
    #[inline]
    pub fn x_row_mut(&mut self, i: usize) -> &mut [f64] {
        vecops::row_mut(&mut self.x, self.p, i)
    }

    /// Copy all iterates out as per-node vectors (the `final_states`
    /// shape of [`crate::coordinator::RunOutput`]).
    pub fn states(&self) -> Vec<Vec<f64>> {
        (0..self.n).map(|i| self.x_row(i).to_vec()).collect()
    }

    /// Churn-plane rejoin masking: reset node `i`'s *own* compression
    /// channel — its mirror row `x̃_i` drops to zero so the next
    /// broadcast re-amplifies from a known origin. With `cold`, the
    /// node's persistent rows (`x`, `grad`, and `aux` when present) are
    /// also zeroed, modeling a crash that lost local state; a warm
    /// rejoin keeps them (last-known restart). The node's mirrors *of
    /// its neighbors* are never touched here — those views re-converge
    /// through normal message flow. Callers must pair this with
    /// [`Self::zero_mirror_slot`] on every live neighbor so both ends
    /// of each mirror channel restart from the same origin.
    pub fn mask_node(&mut self, i: usize, cold: bool) {
        assert!(i < self.n, "node out of range");
        let p = self.p;
        if self.has_mirrors() {
            vecops::row_mut(&mut self.mirror_self, p, i).fill(0.0);
        }
        if cold {
            vecops::row_mut(&mut self.x, p, i).fill(0.0);
            vecops::row_mut(&mut self.grad, p, i).fill(0.0);
            if self.has_aux() {
                vecops::row_mut(&mut self.aux, p, i).fill(0.0);
            }
        }
    }

    /// Churn-plane rejoin masking, receiver side: zero receiver `u`'s
    /// mirror of neighbor slot `slot` (ascending-neighbor order), so
    /// `u`'s view of a rejoined neighbor matches that neighbor's freshly
    /// reset [`mask_node`](Self::mask_node) mirror. No-op on
    /// mirror-free layouts.
    pub fn zero_mirror_slot(&mut self, u: usize, slot: usize) {
        if !self.has_mirrors() {
            return;
        }
        let deg = self.mirror_off[u + 1] - self.mirror_off[u];
        assert!(slot < deg, "mirror slot out of range");
        let base = (self.mirror_off[u] + slot) * self.p;
        self.mirrors[base..base + self.p].fill(0.0);
    }

    /// Borrow node `i`'s rows as one mutable view. The borrow is scoped
    /// to the returned view, so call sites interleave views and shared
    /// reads freely (rule 1 of the module docs).
    pub fn rows(&mut self, i: usize) -> NodeRows<'_> {
        let p = self.p;
        let (m0, m1) = (self.mirror_off[i] * p, self.mirror_off[i + 1] * p);
        NodeRows {
            x: vecops::row_mut(&mut self.x, p, i),
            grad: vecops::row_mut(&mut self.grad, p, i),
            scratch: vecops::row_mut(&mut self.scratch, p, i),
            mirror_self: if self.mirror_self.is_empty() {
                &mut self.mirror_self[..]
            } else {
                vecops::row_mut(&mut self.mirror_self, p, i)
            },
            mirrors: &mut self.mirrors[m0..m1],
            aux: if self.aux.is_empty() {
                &mut self.aux[..]
            } else {
                vecops::row_mut(&mut self.aux, p, i)
            },
            p,
        }
    }

    /// Raw column-view handles for every node, for the dimension-tiled
    /// engine (rule 4: `(node, tile)` work units). Unlike
    /// [`Self::shards`] — whose `&mut` slices force whole-node
    /// exclusivity — a [`NodeColumns`] carries raw row-base pointers so
    /// workers can materialize *column-range* sub-views of the same
    /// node's rows concurrently; the engine's phase barriers are what
    /// make those views disjoint (see [`NodeColumns`] for the
    /// contract). The plane must outlive the handles and must not be
    /// accessed through any other path while they are in use.
    pub fn node_columns(&mut self) -> Vec<NodeColumns> {
        let p = self.p;
        let has_ms = !self.mirror_self.is_empty();
        let mut out = Vec::with_capacity(self.n);
        for i in 0..self.n {
            let deg = self.mirror_off[i + 1] - self.mirror_off[i];
            let moff = self.mirror_off[i] * p;
            out.push(NodeColumns {
                x: unsafe { self.x.as_mut_ptr().add(i * p) },
                grad: unsafe { self.grad.as_mut_ptr().add(i * p) },
                scratch: unsafe { self.scratch.as_mut_ptr().add(i * p) },
                mirror_self: if has_ms {
                    unsafe { self.mirror_self.as_mut_ptr().add(i * p) }
                } else {
                    std::ptr::null_mut()
                },
                mirrors: if deg > 0 {
                    unsafe { self.mirrors.as_mut_ptr().add(moff) }
                } else {
                    std::ptr::null_mut()
                },
                p,
                deg,
            });
        }
        out
    }

    /// Split the plane into disjoint shards at the node boundaries
    /// `bounds` (ascending, starting at 0, ending at `n`). Each shard
    /// owns the rows of its node range exclusively (rule 2 of the
    /// module docs).
    pub fn shards(&mut self, bounds: &[usize]) -> Vec<PlaneShard<'_>> {
        assert!(bounds.len() >= 2, "need at least one shard range");
        assert_eq!(bounds[0], 0, "shard ranges must start at node 0");
        assert_eq!(*bounds.last().unwrap(), self.n, "shard ranges must end at n");
        let p = self.p;
        let has_mirror_self = !self.mirror_self.is_empty();
        let has_aux = !self.aux.is_empty();
        let mut x = &mut self.x[..];
        let mut grad = &mut self.grad[..];
        let mut scratch = &mut self.scratch[..];
        let mut mirror_self = &mut self.mirror_self[..];
        let mut mirrors = &mut self.mirrors[..];
        let mut aux = &mut self.aux[..];
        let mut out = Vec::with_capacity(bounds.len() - 1);
        for w in bounds.windows(2) {
            let (a, b) = (w[0], w[1]);
            assert!(a < b, "shard ranges must be non-empty and ascending");
            let dense = (b - a) * p;
            let (hx, tx) = std::mem::take(&mut x).split_at_mut(dense);
            x = tx;
            let (hg, tg) = std::mem::take(&mut grad).split_at_mut(dense);
            grad = tg;
            let (hs, ts) = std::mem::take(&mut scratch).split_at_mut(dense);
            scratch = ts;
            let (hms, tms) = std::mem::take(&mut mirror_self)
                .split_at_mut(if has_mirror_self { dense } else { 0 });
            mirror_self = tms;
            let mlen = (self.mirror_off[b] - self.mirror_off[a]) * p;
            let (hm, tm) = std::mem::take(&mut mirrors).split_at_mut(mlen);
            mirrors = tm;
            let (ha, ta) =
                std::mem::take(&mut aux).split_at_mut(if has_aux { dense } else { 0 });
            aux = ta;
            out.push(PlaneShard {
                start: a,
                p,
                x: hx,
                grad: hg,
                scratch: hs,
                mirror_self: hms,
                mirrors: hm,
                aux: ha,
                mirror_off: &self.mirror_off[a..=b],
            });
        }
        out
    }
}

/// A mutable view of one node's rows in the plane, handed to
/// [`crate::algorithms::NodeLogic`] for the duration of one call.
/// Fields are public so kernels can take disjoint borrows (e.g. read
/// `scratch` while writing `x`).
pub struct NodeRows<'a> {
    /// The iterate row `x_i`.
    pub x: &'a mut [f64],
    /// The gradient row (persists across rounds — DGD^t captures
    /// `∇f(x^k)` here at phase 0 and applies it at phase `t−1`).
    pub grad: &'a mut [f64],
    /// Workspace row (mixing / amplification / consensus correction).
    /// Contents do not persist across calls.
    pub scratch: &'a mut [f64],
    /// Own mirror `x̃_i` (empty slice for mirror-free layouts).
    pub mirror_self: &'a mut [f64],
    /// Neighbor mirrors, flattened `deg × p` in ascending-neighbor slot
    /// order (empty for mirror-free layouts). Slot `s` occupies
    /// `mirrors[s*p..(s+1)*p]`.
    pub mirrors: &'a mut [f64],
    /// Auxiliary persistent row (empty slice for aux-free layouts).
    /// Unlike `scratch`, contents survive across rounds — CEDAS keeps
    /// its previous-round `ψ` here.
    pub aux: &'a mut [f64],
    /// Row width.
    pub p: usize,
}

/// Raw column-view handle for one node's plane rows, produced by
/// [`StatePlane::node_columns`] (rule 4 of the module docs). Copyable
/// and `Send + Sync` so every worker of the dimension-tiled engine can
/// hold handles for *all* nodes; safety comes from the engine's phase
/// discipline, not the type system:
///
/// * **Tile accessors** (`*_tile`, [`Self::mirror_tile`]) return `&mut`
///   column sub-views. Two live views must never overlap — the engine
///   guarantees this by assigning each `(node, tile)` unit to exactly
///   one worker per phase and separating phases with barriers.
/// * **Row accessors** (`*_row`) return shared full-row views for
///   whole-vector reductions (serial norm passes, the mix phase's
///   reads, observer snapshots). They must not be live while any
///   `&mut` tile view of the same arena row exists — again enforced by
///   phase placement (writes and full-row reads sit in different
///   barrier-separated phases).
#[derive(Debug, Clone, Copy)]
pub struct NodeColumns {
    x: *mut f64,
    grad: *mut f64,
    scratch: *mut f64,
    mirror_self: *mut f64,
    mirrors: *mut f64,
    p: usize,
    deg: usize,
}

// SAFETY: the handle is only a bundle of pointers into the plane's
// arenas; all dereferences go through the unsafe accessors below, whose
// disjointness contract the dimension-tiled engine upholds with phase
// barriers (module docs, rule 4).
unsafe impl Send for NodeColumns {}
unsafe impl Sync for NodeColumns {}

impl NodeColumns {
    /// Row width `p`.
    pub fn p(&self) -> usize {
        self.p
    }

    /// Neighbor-mirror slot count (the node's degree; 0 for mirror-free
    /// layouts).
    pub fn deg(&self) -> usize {
        self.deg
    }

    #[inline]
    unsafe fn tile(base: *mut f64, p: usize, lo: usize, hi: usize) -> &'static mut [f64] {
        debug_assert!(lo <= hi && hi <= p, "column range out of bounds");
        std::slice::from_raw_parts_mut(base.add(lo), hi - lo)
    }

    /// Mutable column sub-view `x[lo..hi]` of the iterate row.
    ///
    /// # Safety
    /// No other live view (mutable or shared) may overlap these columns
    /// of this node's `x` row; the plane must be alive and otherwise
    /// unborrowed (rule 4).
    #[allow(clippy::mut_from_ref)] // raw-pointer view; disjointness is the caller's contract
    #[inline]
    pub unsafe fn x_tile(&self, lo: usize, hi: usize) -> &mut [f64] {
        Self::tile(self.x, self.p, lo, hi)
    }

    /// Mutable column sub-view of the gradient row.
    ///
    /// # Safety
    /// Same contract as [`Self::x_tile`], for the `grad` arena.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub unsafe fn grad_tile(&self, lo: usize, hi: usize) -> &mut [f64] {
        Self::tile(self.grad, self.p, lo, hi)
    }

    /// Mutable column sub-view of the scratch row.
    ///
    /// # Safety
    /// Same contract as [`Self::x_tile`], for the `scratch` arena.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub unsafe fn scratch_tile(&self, lo: usize, hi: usize) -> &mut [f64] {
        Self::tile(self.scratch, self.p, lo, hi)
    }

    /// Mutable column sub-view of the own-mirror row `x̃_i` (mirror
    /// layouts only).
    ///
    /// # Safety
    /// Same contract as [`Self::x_tile`], for the `mirror_self` arena.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub unsafe fn mirror_self_tile(&self, lo: usize, hi: usize) -> &mut [f64] {
        assert!(!self.mirror_self.is_null(), "layout has no mirror arenas");
        Self::tile(self.mirror_self, self.p, lo, hi)
    }

    /// Mutable column sub-view of neighbor-mirror slot `slot` (mirror
    /// layouts only).
    ///
    /// # Safety
    /// Same contract as [`Self::x_tile`], for columns `lo..hi` of mirror
    /// slot `slot`.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub unsafe fn mirror_tile(&self, slot: usize, lo: usize, hi: usize) -> &mut [f64] {
        debug_assert!(slot < self.deg, "mirror slot out of range");
        Self::tile(self.mirrors.add(slot * self.p), self.p, lo, hi)
    }

    /// Shared full iterate row (observer snapshots, whole-vector
    /// reductions).
    ///
    /// # Safety
    /// No live `&mut` view of this node's `x` row may exist (rule 4).
    #[inline]
    pub unsafe fn x_row(&self) -> &[f64] {
        std::slice::from_raw_parts(self.x, self.p)
    }

    /// Shared full scratch row (serial reductions over the staged
    /// compress input).
    ///
    /// # Safety
    /// No live `&mut` view of this node's `scratch` row may exist.
    #[inline]
    pub unsafe fn scratch_row(&self) -> &[f64] {
        std::slice::from_raw_parts(self.scratch, self.p)
    }

    /// Shared full own-mirror row (the mix phase's `self_row` input).
    ///
    /// # Safety
    /// No live `&mut` view of this node's `mirror_self` row may exist.
    #[inline]
    pub unsafe fn mirror_self_row(&self) -> &[f64] {
        assert!(!self.mirror_self.is_null(), "layout has no mirror arenas");
        std::slice::from_raw_parts(self.mirror_self, self.p)
    }

    /// Shared flattened `deg × p` neighbor-mirror block (the mix
    /// phase's `mirrors` input).
    ///
    /// # Safety
    /// No live `&mut` view of any of this node's mirror slots may
    /// exist.
    #[inline]
    pub unsafe fn mirrors_rows(&self) -> &[f64] {
        assert!(self.deg > 0, "node has no mirror slots");
        std::slice::from_raw_parts(self.mirrors, self.deg * self.p)
    }
}

/// A contiguous range of plane rows owned exclusively by one engine
/// worker. Produced by [`StatePlane::shards`].
pub struct PlaneShard<'a> {
    start: usize,
    p: usize,
    x: &'a mut [f64],
    grad: &'a mut [f64],
    scratch: &'a mut [f64],
    mirror_self: &'a mut [f64],
    mirrors: &'a mut [f64],
    aux: &'a mut [f64],
    /// Global mirror offsets for this shard's nodes (`len + 1` entries);
    /// local offsets are rebased against `mirror_off[0]`.
    mirror_off: &'a [usize],
}

impl PlaneShard<'_> {
    /// First global node index of this shard.
    pub fn start(&self) -> usize {
        self.start
    }

    /// Borrow the rows of global node `i` (must lie in this shard).
    pub fn rows(&mut self, i: usize) -> NodeRows<'_> {
        let l = i - self.start;
        let p = self.p;
        let base = self.mirror_off[0];
        let m0 = (self.mirror_off[l] - base) * p;
        let m1 = (self.mirror_off[l + 1] - base) * p;
        NodeRows {
            x: vecops::row_mut(self.x, p, l),
            grad: vecops::row_mut(self.grad, p, l),
            scratch: vecops::row_mut(self.scratch, p, l),
            mirror_self: if self.mirror_self.is_empty() {
                &mut self.mirror_self[..]
            } else {
                vecops::row_mut(self.mirror_self, p, l)
            },
            mirrors: &mut self.mirrors[m0..m1],
            aux: if self.aux.is_empty() {
                &mut self.aux[..]
            } else {
                vecops::row_mut(self.aux, p, l)
            },
            p,
        }
    }

    /// Read the iterate row of global node `i` (must lie in this shard).
    #[inline]
    pub fn x_row(&self, i: usize) -> &[f64] {
        vecops::row(self.x, self.p, i - self.start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_plane_rows_are_disjoint_and_indexed() {
        let mut plane = StatePlane::new(&PlaneLayout::dense(3, 2));
        for i in 0..3 {
            let rows = plane.rows(i);
            rows.x.copy_from_slice(&[i as f64, 10.0 + i as f64]);
            rows.grad.fill(i as f64);
            rows.scratch.fill(-(i as f64));
            assert!(rows.mirror_self.is_empty());
            assert!(rows.mirrors.is_empty());
        }
        assert_eq!(plane.x_row(1), &[1.0, 11.0]);
        assert_eq!(plane.states(), vec![vec![0.0, 10.0], vec![1.0, 11.0], vec![2.0, 12.0]]);
        assert!(!plane.has_mirrors());
    }

    #[test]
    fn mirror_plane_slots_follow_offsets() {
        // Degrees 1, 2, 1 → mirror rows at offsets [0, 1, 3, 4].
        let mut plane = StatePlane::new(&PlaneLayout::with_mirrors(3, 2, vec![1, 2, 1]));
        assert!(plane.has_mirrors());
        {
            let rows = plane.rows(1);
            assert_eq!(rows.mirror_self.len(), 2);
            assert_eq!(rows.mirrors.len(), 4); // 2 slots × p=2
            rows.mirrors[2..4].copy_from_slice(&[7.0, 8.0]); // slot 1
        }
        let rows0 = plane.rows(0);
        assert_eq!(rows0.mirrors.len(), 2);
        assert_eq!(rows0.mirrors, &[0.0, 0.0]);
        let rows1 = plane.rows(1);
        assert_eq!(&rows1.mirrors[2..4], &[7.0, 8.0]);
    }

    #[test]
    fn shards_partition_the_plane() {
        let mut plane = StatePlane::new(&PlaneLayout::with_mirrors(5, 1, vec![2, 2, 2, 2, 2]));
        {
            let mut shards = plane.shards(&[0, 2, 5]);
            assert_eq!(shards.len(), 2);
            assert_eq!(shards[0].start(), 0);
            assert_eq!(shards[1].start(), 2);
            // Write through shard views at global indices.
            for i in 0..5 {
                let shard = if i < 2 { &mut shards[0] } else { &mut shards[1] };
                let rows = shard.rows(i);
                rows.x[0] = 100.0 + i as f64;
                rows.mirrors[0] = i as f64; // slot 0
            }
            assert_eq!(shards[1].x_row(4), &[104.0]);
        }
        for i in 0..5 {
            assert_eq!(plane.x_row(i), &[100.0 + i as f64]);
            assert_eq!(plane.rows(i).mirrors[0], i as f64);
        }
    }

    #[test]
    fn aux_rows_persist_and_shard() {
        let mut plane = StatePlane::new(&PlaneLayout::dense(4, 2).with_aux());
        assert!(plane.has_aux());
        assert!(!plane.has_mirrors());
        for i in 0..4 {
            let rows = plane.rows(i);
            assert_eq!(rows.aux.len(), 2);
            rows.x.fill(i as f64);
            rows.aux[1] = 10.0 + i as f64;
        }
        assert_eq!(plane.aux_row(2), &[0.0, 12.0]);
        {
            let mut shards = plane.shards(&[0, 2, 4]);
            let rows = shards[1].rows(3);
            assert_eq!(rows.aux, &[0.0, 13.0]);
            rows.aux[0] = -1.0;
        }
        assert_eq!(plane.aux_row(3), &[-1.0, 13.0]);
        // The ψ⁰ = x⁰ seeding convention copies iterates wholesale.
        plane.seed_aux_from_x();
        for i in 0..4 {
            assert_eq!(plane.aux_row(i), plane.x_row(i));
        }
        // Aux-free layouts expose empty aux rows.
        let mut dense = StatePlane::new(&PlaneLayout::dense(2, 2));
        assert!(!dense.has_aux());
        assert!(dense.rows(0).aux.is_empty());
    }

    #[test]
    fn shards_cross_thread() {
        let mut plane = StatePlane::new(&PlaneLayout::dense(4, 3));
        let shards = plane.shards(&[0, 1, 2, 3, 4]);
        std::thread::scope(|scope| {
            for (w, mut shard) in shards.into_iter().enumerate() {
                scope.spawn(move || {
                    shard.rows(w).x.fill(w as f64 + 0.5);
                });
            }
        });
        for i in 0..4 {
            assert_eq!(plane.x_row(i), &[i as f64 + 0.5; 3]);
        }
    }

    #[test]
    #[should_panic(expected = "must end at n")]
    fn shards_reject_partial_cover() {
        let mut plane = StatePlane::new(&PlaneLayout::dense(4, 1));
        let _ = plane.shards(&[0, 2]);
    }

    #[test]
    fn tile_bounds_are_8_aligned_and_cover() {
        for &(p, tiles) in &[(37usize, 5usize), (64, 4), (1, 5), (8, 1), (1 << 20, 16), (19, 2)] {
            let b = tile_bounds(p, tiles);
            assert_eq!(b[0], 0);
            assert_eq!(*b.last().unwrap(), p);
            assert!(b.len() - 1 <= tiles, "p={p} tiles={tiles}: too many tiles");
            for w in b.windows(2) {
                assert!(w[0] < w[1], "empty tile at p={p} tiles={tiles}");
            }
            for &e in &b[1..b.len() - 1] {
                assert_eq!(e % 8, 0, "interior boundary {e} not 8-aligned");
            }
        }
        // Exact split when everything divides.
        assert_eq!(tile_bounds(32, 4), vec![0, 8, 16, 24, 32]);
        // Small p degenerates to one tile.
        assert_eq!(tile_bounds(3, 4), vec![0, 3]);
    }

    #[test]
    fn mask_node_resets_the_rejoin_channel_only() {
        // Degrees 2, 1, 1 on a path-ish layout; p = 2.
        let mut plane = StatePlane::new(&PlaneLayout::with_mirrors(3, 2, vec![2, 1, 1]).with_aux());
        for i in 0..3 {
            let rows = plane.rows(i);
            rows.x.fill(1.0 + i as f64);
            rows.grad.fill(2.0);
            rows.mirror_self.fill(3.0);
            rows.mirrors.fill(4.0);
            rows.aux.fill(5.0);
        }
        // Warm rejoin of node 1: own mirror drops, x/grad/aux survive,
        // mirrors-of-others survive.
        plane.mask_node(1, false);
        {
            let rows = plane.rows(1);
            assert_eq!(rows.mirror_self, &[0.0, 0.0]);
            assert_eq!(rows.x, &[2.0, 2.0]);
            assert_eq!(rows.grad, &[2.0, 2.0]);
            assert_eq!(rows.aux, &[5.0, 5.0]);
            assert_eq!(rows.mirrors, &[4.0, 4.0]);
        }
        // Receiver side: node 0 zeroes its mirror slot 1 (of node 1,
        // say); slot 0 is untouched.
        plane.zero_mirror_slot(0, 1);
        {
            let rows = plane.rows(0);
            assert_eq!(&rows.mirrors[..2], &[4.0, 4.0]);
            assert_eq!(&rows.mirrors[2..], &[0.0, 0.0]);
        }
        // Cold rejoin of node 2 wipes persistent rows too.
        plane.mask_node(2, true);
        {
            let rows = plane.rows(2);
            assert_eq!(rows.x, &[0.0, 0.0]);
            assert_eq!(rows.grad, &[0.0, 0.0]);
            assert_eq!(rows.aux, &[0.0, 0.0]);
            assert_eq!(rows.mirror_self, &[0.0, 0.0]);
        }
        // Mirror-free layouts: mask still clears dense rows, slot-zero
        // is a no-op.
        let mut dense = StatePlane::new(&PlaneLayout::dense(2, 2));
        dense.rows(0).x.fill(9.0);
        dense.zero_mirror_slot(0, 0);
        dense.mask_node(0, true);
        assert_eq!(dense.x_row(0), &[0.0, 0.0]);
    }

    #[test]
    fn node_columns_views_alias_the_plane_rows() {
        let mut plane = StatePlane::new(&PlaneLayout::with_mirrors(3, 10, vec![2, 1, 2]));
        for i in 0..3 {
            let rows = plane.rows(i);
            for (e, v) in rows.x.iter_mut().enumerate() {
                *v = (i * 100 + e) as f64;
            }
            rows.mirror_self.fill(i as f64 + 0.5);
            rows.mirrors.fill(-(i as f64));
        }
        let cols = plane.node_columns();
        assert_eq!(cols.len(), 3);
        assert_eq!(cols[1].p(), 10);
        assert_eq!(cols[1].deg(), 1);
        // SAFETY (test): single thread, views created and dropped one at
        // a time, plane untouched while handles are live.
        unsafe {
            assert_eq!(cols[2].x_tile(8, 10), &[208.0, 209.0]);
            assert_eq!(&cols[1].x_row()[..2], &[100.0, 101.0]);
            assert_eq!(cols[0].mirror_self_row(), &[0.5; 10]);
            assert_eq!(cols[2].mirror_tile(1, 0, 3), &[-2.0; 3]);
            assert_eq!(cols[2].mirrors_rows().len(), 20);
            cols[0].scratch_tile(0, 8).fill(7.0);
            cols[0].grad_tile(3, 5).fill(9.0);
        }
        drop(cols);
        assert_eq!(plane.rows(0).scratch[..8], [7.0; 8]);
        assert_eq!(plane.rows(0).grad[3..5], [9.0; 2]);
    }
}
