//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated timed runs with mean / p50 / p95 summary —
//! enough to drive the `cargo bench` targets and the §Perf iteration log.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Label.
    pub name: String,
    /// Samples (seconds per iteration).
    pub samples: Vec<f64>,
}

impl BenchResult {
    /// Mean seconds.
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Percentile (0–100) seconds.
    pub fn percentile(&self, p: f64) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx]
    }

    /// Human summary line.
    pub fn summary(&self) -> String {
        format!(
            "{:<44} mean {:>12} p50 {:>12} p95 {:>12} ({} samples)",
            self.name,
            fmt_dur(self.mean()),
            fmt_dur(self.percentile(50.0)),
            fmt_dur(self.percentile(95.0)),
            self.samples.len()
        )
    }
}

fn fmt_dur(sec: f64) -> String {
    if sec >= 1.0 {
        format!("{sec:.3} s")
    } else if sec >= 1e-3 {
        format!("{:.3} ms", sec * 1e3)
    } else if sec >= 1e-6 {
        format!("{:.3} µs", sec * 1e6)
    } else {
        format!("{:.1} ns", sec * 1e9)
    }
}

/// Run `f` repeatedly: `warmup` unmeasured runs, then measured runs until
/// either `samples` samples are collected or `max_time` elapses (at least
/// 3 samples regardless).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, max_time: Duration, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(samples);
    let start = Instant::now();
    while out.len() < samples && (out.len() < 3 || start.elapsed() < max_time) {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), samples: out }
}

/// Convenience: bench with defaults (2 warmup, 10 samples, 10 s budget)
/// and print the summary line.
pub fn bench_print<F: FnMut()>(name: &str, f: F) -> BenchResult {
    let r = bench(name, 2, 10, Duration::from_secs(10), f);
    println!("{}", r.summary());
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_stats() {
        let r = bench("noop", 1, 5, Duration::from_secs(1), || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.samples.len(), 5);
        assert!(r.mean() >= 0.0);
        assert!(r.percentile(95.0) >= r.percentile(50.0) - 1e-9);
        assert!(r.summary().contains("noop"));
    }

    #[test]
    fn respects_time_budget() {
        let r = bench("sleepy", 0, 1000, Duration::from_millis(50), || {
            std::thread::sleep(Duration::from_millis(10));
        });
        assert!(r.samples.len() < 1000);
        assert!(r.samples.len() >= 3);
    }
}
