//! Minimal JSON parser + writer.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`, written
//! by `python/compile/aot.py`) and for experiment reports. Supports the
//! full JSON grammar except `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (stored as f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (order-preserving not required; BTreeMap for determinism).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Get object member.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As usize (must be a non-negative integral number).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// As str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        c => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_document() {
        let doc = r#"{"name":"model","shapes":[[2,3],[4]],"f":1.5,"neg":-2e3,"ok":true,"none":null,"esc":"a\"b\n"}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("model"));
        assert_eq!(v.get("f").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("neg").unwrap().as_f64(), Some(-2000.0));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("none"), Some(&Json::Null));
        assert_eq!(v.get("esc").unwrap().as_str(), Some("a\"b\n"));
        let shapes = v.get("shapes").unwrap().as_arr().unwrap();
        assert_eq!(shapes[0].as_arr().unwrap()[1].as_usize(), Some(3));
        // Round trip.
        let again = parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn parses_nested_and_whitespace() {
        let v = parse(" { \"a\" : [ { \"b\" : 1 } ] } ").unwrap();
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[0].get("b").unwrap().as_usize(),
            Some(1)
        );
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
