//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `binary <subcommand> [--flag] [--key value]...`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional token (the subcommand), if any.
    pub command: Option<String>,
    /// `--key value` pairs.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
    /// Remaining positionals after the subcommand.
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse from the process arguments.
    pub fn from_env() -> Result<Self, String> {
        Self::parse(std::env::args().skip(1))
    }

    /// String option with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Typed option with default; errors on unparseable values.
    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|_| format!("invalid value for --{key}: {v}")),
        }
    }

    /// Is a bare flag present?
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_options_flags() {
        // Note: `--verbose extra` would bind as an option (greedy value
        // consumption); a flag is only recognized before another `--`
        // token or at the end.
        let a = parse("run extra --exp fig5 --iters 1000 --verbose");
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.get_str("exp", ""), "fig5");
        assert_eq!(a.get::<usize>("iters", 0).unwrap(), 1000);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positionals, vec!["extra"]);
    }

    #[test]
    fn equals_syntax() {
        let a = parse("bench --gamma=0.8");
        assert_eq!(a.get::<f64>("gamma", 0.0).unwrap(), 0.8);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("run");
        assert_eq!(a.get::<usize>("iters", 42).unwrap(), 42);
        let b = parse("run --iters abc");
        assert!(b.get::<usize>("iters", 0).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --quiet");
        assert!(a.has_flag("quiet"));
        assert!(a.options.is_empty());
    }
}
