//! Minimal TOML-subset config parser (no serde/toml crates offline).
//!
//! Supported grammar — enough for experiment configs:
//!
//! ```toml
//! # comment
//! key = "string"
//! other = 1.5
//! flag = true
//! [section]
//! nested = 3
//! ```
//!
//! Values: strings (double-quoted), numbers (f64), booleans. Keys are
//! flattened as `section.key`.

use std::collections::BTreeMap;

/// A parsed config value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// Number (always f64).
    Num(f64),
    /// Boolean.
    Bool(bool),
}

/// Flat key → value map with typed accessors.
#[derive(Debug, Clone, Default)]
pub struct Config {
    map: BTreeMap<String, Value>,
}

impl Config {
    /// Parse a TOML-subset document.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                let name = name.trim();
                if name.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                section = name.to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let full_key =
                if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            let val = parse_value(val.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            map.insert(full_key, val);
        }
        Ok(Self { map })
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::parse(&text)
    }

    /// Raw value.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    /// String accessor with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        match self.map.get(key) {
            Some(Value::Str(s)) => s.clone(),
            _ => default.to_string(),
        }
    }

    /// f64 accessor with default.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        match self.map.get(key) {
            Some(Value::Num(n)) => *n,
            _ => default,
        }
    }

    /// usize accessor with default (floors the stored number).
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        match self.map.get(key) {
            Some(Value::Num(n)) if *n >= 0.0 => *n as usize,
            _ => default,
        }
    }

    /// bool accessor with default.
    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        match self.map.get(key) {
            Some(Value::Bool(b)) => *b,
            _ => default,
        }
    }

    /// All keys (for validation / error messages).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // A `#` outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    s.parse::<f64>().map(Value::Num).map_err(|_| format!("cannot parse value: {s}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_and_sections() {
        let c = Config::parse(
            r#"
# experiment config
algo = "adc"      # trailing comment
alpha = 0.02
iters = 1000
verbose = true

[link]
drop_prob = 0.05
"#,
        )
        .unwrap();
        assert_eq!(c.get_str("algo", ""), "adc");
        assert_eq!(c.get_f64("alpha", 0.0), 0.02);
        assert_eq!(c.get_usize("iters", 0), 1000);
        assert!(c.get_bool("verbose", false));
        assert_eq!(c.get_f64("link.drop_prob", 0.0), 0.05);
        assert_eq!(c.keys().count(), 5);
    }

    #[test]
    fn defaults_on_missing_or_wrong_type() {
        let c = Config::parse("x = \"str\"").unwrap();
        assert_eq!(c.get_f64("x", 7.0), 7.0);
        assert_eq!(c.get_f64("missing", 7.0), 7.0);
        assert_eq!(c.get_str("x", ""), "str");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("x = \"unterminated").is_err());
        assert!(Config::parse("[]").is_err());
        assert!(Config::parse("x = notanumber").is_err());
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let c = Config::parse("x = \"a#b\"").unwrap();
        assert_eq!(c.get_str("x", ""), "a#b");
    }
}
