//! Small self-contained utilities (the offline environment has no serde /
//! clap / criterion, so the pieces we need are implemented here).

pub mod args;
pub mod bench;
pub mod config;
pub mod json;
