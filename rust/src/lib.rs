//! # adcdgd — Amplified-Differential Compression DGD
//!
//! A production-grade reproduction of *"Compressed Distributed Gradient
//! Descent: Communication-Efficient Consensus over Networks"*
//! (Zhang, Liu, Zhu, Bentley; 2018).
//!
//! The library is a three-layer system:
//!
//! * **rust coordinator** (this crate): the decentralized-consensus
//!   runtime — topologies, consensus matrices, compression operators with
//!   exact wire-byte accounting, the algorithm family (DGD, DGD^t, naive
//!   compressed DGD, ADC-DGD, QDGD, plus the stochastic CHOCO-SGD and
//!   CEDAS), a simulated network fabric, a stochastic data plane for
//!   sharded minibatch workloads, and the experiment harness
//!   regenerating every figure in the paper.
//! * **JAX models** (`python/compile/model.py`): ML objectives
//!   (logistic regression, transformer LM) AOT-lowered to HLO text.
//! * **Pallas kernels** (`python/compile/kernels/`): the compression and
//!   matmul hot-spots, checked against a pure-jnp oracle.
//!
//! The rust binary executes HLO artifacts through the PJRT C API (`xla`
//! crate) — python never runs on the request path. (Offline builds link
//! an inert vendored stub that reports PJRT unavailable; artifact-backed
//! paths self-skip.)
//!
//! ## The ScenarioSpec model
//!
//! Every run in the crate — experiments, examples, the CLI, tests — is a
//! *data declaration*: a [`coordinator::ScenarioSpec`] names the
//! algorithm ([`algorithms::AlgorithmKind`]), topology
//! ([`coordinator::TopologySpec`]), consensus-weight construction
//! ([`coordinator::WeightSpec`]), per-node objectives
//! ([`coordinator::ObjectiveSpec`]), compression operator
//! ([`coordinator::CompressorSpec`]), and a [`coordinator::RunConfig`]
//! (iterations, step schedule, seed, link model, engine). The single
//! execution entry point [`coordinator::run_scenario`] materializes the
//! spec through the [`algorithms::AlgorithmKind`] node-factory registry
//! and executes it on the selected engine:
//!
//! | Engine | Threads | Use case |
//! |---|---|---|
//! | [`EngineKind::Sequential`] | 1 | reference semantics, tests |
//! | [`EngineKind::Threaded`] | one per node | real contention, small n |
//! | [`EngineKind::Pool`] | `min(num_cpus, n)` sharded workers | large n |
//! | [`EngineKind::Dim`] | `min(num_cpus, n × tiles)` over `(node, tile)` units | large P, small n |
//!
//! All engines are bit-identical given the same seeds (per-node RNG
//! streams, stateless-hash loss injection, sender-sorted reduction).
//! For repeated trials, [`coordinator::ScenarioSpec::prepare`] builds
//! the graph/weights/objectives once and
//! [`coordinator::PreparedScenario::run_with`] reruns cheaply.
//!
//! ## The state plane
//!
//! All per-node vectors of a run — iterates, gradients, scratch, and
//! ADC-DGD's mirror estimates — live in one arena, the
//! [`state::StatePlane`], as contiguous row-major matrices; nodes
//! borrow [`state::NodeRows`] views per call and the parallel engines
//! split the plane into disjoint contiguous [`state::PlaneShard`]s (see
//! [`state`] for the borrowing rules). Consensus weights are shared in
//! CSR form ([`consensus::CsrWeights`], `O(E)` instead of `O(N²)`), so
//! the fleet-wide mixing step `x^{k+1} = Z x̃^k − α_k ∇f(x^k)` (paper
//! Eq. 10) executes as a row-parallel sparse × dense product with a
//! fixed per-row reduction order — which is what keeps all three
//! engines bit-identical.
//!
//! ## The mailbox plane
//!
//! Message delivery follows the same discipline ([`network::mailbox`]):
//! every *(receiver, incoming-neighbor)* pair owns one fixed slot on
//! the topology's neighbor-offset table, so inboxes are consumed as
//! borrowed [`network::InboxView`]s in structural ascending-sender
//! order — no per-round allocation, sorting, or sender merging on the
//! broadcast → slot → consume path. When the link model sets a round
//! cadence ([`network::LinkModel::round_secs`]), latency and bandwidth
//! translate into messages that arrive whole rounds late through an
//! in-flight ring of recycled buckets ([`network::LinkModel::with_delay`]
//! pins a uniform delay; `adcdgd run --exp delay` sweeps the staleness
//! axis), and every message carries its send round so algorithms can
//! decode stale payloads exactly.
//!
//! ## The encode plane
//!
//! The send side follows the same zero-allocation discipline as the
//! state and mailbox planes. Every operator's kernel is
//! [`compress::Compressor::compress_into`], which draws its randomness
//! as one block per message ([`rng::Xoshiro256pp::fill_u64`], converted
//! per element with [`rng::block_f64`] — bit-identical to the scalar
//! `next_f64` sequence, so golden trajectories are preserved) and
//! writes into a reusable [`compress::PayloadBuf`]. Each engine worker
//! owns a [`compress::PayloadPool`] that recycles the outgoing
//! `Arc<Payload>` cells in place once receivers clear their mailbox
//! slots:
//!
//! ```text
//!          compress_into                 emit + Arc::get_mut swap
//! z ──────▶ PayloadBuf arenas ─────────▶ Arc<Payload> cell ──clone──▶ slots
//!              ▲                              │ (pool keeps one clone)    │
//!              └── reclaim(previous payload) ◀┴── strong count → 1 ◀──────┘
//! ```
//!
//! Allocation accounting: warm-up may allocate (cells up to the
//! pipeline depth of ~`2 + delay` per node, arena growth, ring
//! buckets); steady-state rounds allocate **nothing** — asserted by the
//! `ADCDGD_BENCH_ONLY=encode` hotpath section on full compress →
//! broadcast → consume rounds at n ∈ {16, 256, 2048}. Payloads the
//! mailbox drops as their last reference (non-pooled senders) are
//! retired and salvaged back into the pool through
//! [`network::Bus::reclaim_retired`]. Every run surfaces its summed
//! pool-cell creation count as
//! [`coordinator::RunOutput::fresh_payload_cells`], so pool-recycling
//! health is observable outside the benches.
//!
//! ## The stochastic plane
//!
//! The fourth plane ([`stochastic`]) opens the *minibatch* scenario
//! axis: a [`stochastic::DataPlane`] holds every node's sample shard in
//! one contiguous arena (CSR-style per-node offsets, synthesized from
//! the driver's deterministic per-node streams), a
//! [`stochastic::SampleOracle`] yields seeded minibatch index blocks on
//! a fixed-draw-per-epoch contract (the sampling analogue of the encode
//! plane's block-RNG contract — draws are bit-reproducible and
//! independent of engine or worker count), and
//! [`stochastic::ShardObjective`] layers logistic / least-squares
//! losses over a shard with `minibatch_grad_into` writing straight into
//! [`state::NodeRows`] rows. Two stochastic algorithms ride on it:
//! CHOCO-SGD ([`algorithms::ChocoSgdNode`] — compressed-difference
//! gossip whose estimate rows live in the plane's mirror arenas; with
//! zero compression error and consensus step 1 it reduces to DGD
//! *bit-exactly*) and CEDAS ([`algorithms::CedasNode`] — compressed
//! exact diffusion, whose `ψ` correction occupies the plane's `aux`
//! row and removes DGD's constant-step bias). `adcdgd run --exp
//! stochastic` sweeps bytes-to-accuracy against ADC-DGD at matched
//! compression budgets, and the `ADCDGD_BENCH_ONLY=stochastic` hotpath
//! section asserts the sample → encode → consume round allocates
//! nothing in steady state.
//!
//! ## The wire plane
//!
//! The paper's byte accounting ([`compress::Payload::wire_bytes`] —
//! 2 B/element int16, 8 B/element double, 2 bits/element ternary) is a
//! *model*; the wire plane ([`compress::wire`]) makes it *measurable*
//! by serializing every payload into a real byte stream:
//! [`compress::encode_into`] writes a 5-byte frame (kind tag + length),
//! the quantization scale where one exists, then a variant-specific
//! body — raw little-endian words for dense payloads, varint
//! nnz/delta-coded indices for sparse ones, and a static-model **rANS
//! entropy coder** over ternary code streams (per-message symbol counts
//! in the header; a 1-byte mode escapes to verbatim packed bytes
//! whenever entropy coding would not win, so the stream never exceeds
//! the packed size plus the fixed header). [`compress::decode_from`]
//! parses a stream back bit-exactly, validating every length, count,
//! index gap, and the final coder state, into the same
//! [`compress::PayloadBuf`] arenas the encode plane recycles — encode →
//! wire → decode → consume allocates nothing in steady state
//! ([`compress::WireBuf`] and the arenas reserve worst-case bounds up
//! front; asserted by the `ADCDGD_BENCH_ONLY=wire` hotpath section).
//! The [`network::Bus`] meters both columns per link: modeled bytes
//! keep driving the simulated clock and the goldens, while
//! [`coordinator::RunOutput::measured_wire_bytes`] reports what the
//! serializer actually put on the wire (`solve` prints both; `run --exp
//! stochastic` records both axes per trajectory).
//!
//! ## The dimension plane
//!
//! The pool engine parallelizes over *nodes*, so a 16-node fleet can
//! never occupy more than 16 cores — even when each round moves
//! megabytes per node. The dimension plane ([`engine::dim`],
//! [`EngineKind::Dim`]) adds the second axis: the coordinate range
//! `0..P` is split into contiguous 8-aligned column tiles
//! ([`state::tile_bounds`]) and the per-round hot path — consensus mix
//! ([`consensus::CsrWeights::mix_row_range_into`]), gradient + step
//! ([`objective::Objective::grad_range_into`]), payload consume
//! ([`compress::Payload::decode_axpy_range`]), and quantization
//! ([`compress::Compressor::encode_tile`]) — executes as `(node, tile)`
//! work units claimed dynamically from a shared queue by
//! `min(cores, n × tiles)` workers. Whole-vector reductions that are
//! not associativity-safe (TernGrad's `max|z|` is; QSGD's `‖z‖₂` is
//! not) stay serial per node inside
//! [`compress::Compressor::stage_into`], which also draws the
//! message's single block-RNG batch — so every tile count reproduces
//! the sequential engine **bit-for-bit** (pinned against the golden
//! trajectories in `tests/engine_equivalence.rs` and kernel-by-kernel
//! in `tests/properties.rs`). Fleets that are not tileable (no
//! [`algorithms::TiledCtx`], a compressor without staged kernels, or a
//! non-separable objective) silently fall back to the pool engine.
//! Steady-state rounds allocate nothing — asserted by the
//! `ADCDGD_BENCH_ONLY=dim` hotpath section, which sweeps
//! n = 16 × P ∈ {65 536, 1 048 576} × tiles ∈ {1, 4, 8, 16} and writes
//! `BENCH_dim_plane.json`.
//!
//! ## The churn plane
//!
//! Real deployments lose nodes mid-run; the churn plane
//! ([`network::TopologySchedule`]) makes membership a *scenario axis*
//! rather than a rewrite. A schedule scripts planned joins and leaves
//! on an epoch cadence ([`network::ChurnEvent`]), a Markov per-link
//! up/down chain ([`network::LinkFlap`]), and per-node straggler delay
//! distributions ([`network::DelayDist`]) that ride the mailbox plane's
//! existing in-flight ring. Attach it with
//! [`coordinator::ScenarioSpec::with_churn`] (CLI: the `--churn-*`
//! flags) and the driver runs the fleet in epoch segments:
//!
//! ```text
//! epoch e boundary (single-threaded, engine-agnostic)
//!   1. apply scripted leaves/joins; rejoiners get their compression
//!      channel reset on both ends (mask_node + neighbor mirror slots)
//!   2. step the per-edge Markov flap chain (transport-only)
//!   3. hygiene: drain dead inboxes, retire in-flight traffic to dead
//!      destinations through the encode plane's reclaim hook (counted
//!      in RunOutput::churn, never leaked)
//!   4. incremental relayout: O(E) in-place Metropolis reweight of the
//!      live subgraph into the inactive buffer of a two-buffer Arc
//!      weight bank (CsrWeights::reweight_metropolis_live), then every
//!      node rebinds — two CSR allocations for the whole run
//! epoch e rounds (any engine, alive-masked run_segment)
//!   dead nodes neither send, consume, nor draw randomness — their
//!   iterates and RNG streams freeze, so a warm rejoin resumes exactly
//!   where the crash left them; cold rejoin restarts from x = 0
//!   ([`network::RejoinPolicy`])
//! ```
//!
//! **Determinism contract**: every fault draw — who is down, which
//! links flap, which broadcasts straggle — is a stateless hash of the
//! churn seed (`cfg.seed ^ 0xC0C0`), never a stateful RNG, and the loss
//! trace keys on global `(src, dst, round)`; so all four engines unfold
//! a scripted fault trace **bit-identically** (pinned in
//! `tests/churn_plane.rs`), and an attached-but-empty schedule
//! reproduces the churn-free pathway bit-for-bit. Fault totals surface
//! as [`coordinator::RunOutput::churn`]
//! ([`network::ChurnCounters`]). `adcdgd run --exp churn` sweeps
//! join/leave storms ([`network::TopologySchedule::storm`]), and the
//! `ADCDGD_BENCH_ONLY=churn` hotpath section measures relayout cost per
//! boundary and alive-masked round throughput at n ∈ {256, 2048} with
//! 1% churn per epoch, asserting in-epoch rounds allocate nothing
//! (`BENCH_churn_plane.json`).
//!
//! ## The telemetry plane
//!
//! Observability follows the same pre-register-then-store discipline
//! as every other plane ([`telemetry`]): a [`telemetry::Registry`] of
//! typed counters/gauges/histograms is populated at build time and
//! updated by plain `Cell` stores; span-style [`telemetry::PhaseTimers`]
//! accumulate wall time per engine round-loop phase (sequential's
//! compress/broadcast/deliver/consume/reclaim/observe, the
//! threaded/pool coordinator barrier segments, and the dim engine's
//! seven A–E2 gates — the tables live in [`telemetry::phases`]); and
//! per-link/per-node rollups unify what the [`network::Bus`], the
//! mailbox plane, the payload pools, and the churn driver already
//! count privately. Three rules keep it safe to leave on (the
//! default; [`coordinator::RunConfig::telemetry`], CLI
//! `--no-telemetry`):
//!
//! 1. **Observational only** — wall time never feeds the simulated
//!    clock, the RNG streams, or any golden quantity, so every
//!    bit-identity suite passes with telemetry on or off
//!    (`tests/engine_equivalence.rs` pins it).
//! 2. **Zero steady-state allocation** — recording a span is two
//!    monotonic clock reads and two `Cell` stores; the
//!    `ADCDGD_BENCH_ONLY=telemetry` hotpath section asserts zero
//!    allocations with full instrumentation at n ∈ {16, 256, 2048} and
//!    reports the on/off overhead (`BENCH_telemetry_plane.json`).
//! 3. **Single-writer** — only the engine's calling/coordinator thread
//!    records (`Cell` is `!Sync`, so the compiler enforces it); in the
//!    parallel engines phases are coordinator barrier/gate segments.
//!
//! Every run ends in a [`coordinator::RunOutput::telemetry`] rollup
//! ([`telemetry::TelemetrySummary`]: phase rows, fleet counters,
//! per-node rollups — `solve` prints its one-line form), and
//! `solve --trace out.jsonl` exports the schema-versioned JSONL trace
//! ([`telemetry::trace`], v1: a meta line, then one object per
//! recorded round whose byte columns equal
//! [`coordinator::RunOutput::metrics`] exactly; validated in CI by
//! `scripts/check_trace_schema.py`). `run --exp trace` sweeps the
//! ADC-DGD vs CHOCO-SGD phase-time breakdown at n ∈ {256, 2048}.
//!
//! Related: [`coordinator::RunConfig::measure_wire`] (default on)
//! controls whether every broadcast additionally runs the wire plane's
//! real serializer for measured byte counts; modeled-only studies and
//! the scale bench turn it off (`--no-measure-wire`) to keep the round
//! loop free of the per-message rANS pass.
//!
//! [`EngineKind::Sequential`]: coordinator::EngineKind::Sequential
//! [`EngineKind::Threaded`]: coordinator::EngineKind::Threaded
//! [`EngineKind::Pool`]: coordinator::EngineKind::Pool
//! [`EngineKind::Dim`]: coordinator::EngineKind::Dim
//!
//! ## Example
//!
//! Solve the paper's four-node consensus problem with ADC-DGD (`no_run`
//! to keep `cargo test` fast; the same flow executes in
//! `examples/quickstart.rs` and the integration tests):
//!
//! ```no_run
//! use adcdgd::prelude::*;
//!
//! let spec = ScenarioSpec::paper4(AlgorithmKind::AdcDgd(AdcDgdOptions { gamma: 1.0 }))
//!     .with_compressor(CompressorSpec::RandomizedRounding)
//!     .with_config(RunConfig {
//!         iterations: 600,
//!         step_size: StepSize::Constant(0.02),
//!         record_every: 100,
//!         ..RunConfig::default()
//!     });
//! let out = run_scenario(&spec);
//! // Converges to the paper's optimum f* ≈ 0.292 while sending
//! // 2 B/element instead of DGD's 8.
//! assert!((out.metrics.objective.last().unwrap() - 0.292).abs() < 0.01);
//! ```

#![warn(missing_docs)]

pub mod algorithms;
pub mod compress;
pub mod experiments;
pub mod consensus;
pub mod coordinator;
pub mod engine;
pub mod linalg;
pub mod metrics;
pub mod network;
pub mod objective;
pub mod rng;
pub mod runtime;
pub mod state;
pub mod stochastic;
pub mod telemetry;
pub mod topology;
pub mod util;

/// Convenient glob-import surface.
pub mod prelude {
    pub use crate::algorithms::{
        AdcDgdOptions, AlgorithmKind, CedasOptions, ChocoSgdOptions, CompressorRef, Fleet,
        ObjectiveRef, QdgdOptions, StepSize,
    };
    pub use crate::compress::{
        decode_from, encode_into, Compressor, Identity, LowPrecisionQuantizer, PayloadBuf,
        PayloadPool, Qsgd, QuantizationSparsifier, RandomizedRounding, TernGrad, WireBuf,
        WireError,
    };
    pub use crate::consensus::{
        metropolis, metropolis_csr, paper_four_node_w, ConsensusMatrix, CsrWeights, Weights,
    };
    pub use crate::network::{Bus, InboxMsg, InboxView, LinkModel, MailboxLayout};
    pub use crate::coordinator::{
        run_scenario, CompressorSpec, EngineKind, ObjectiveSpec, PreparedScenario, RunConfig,
        RunOutput, ScenarioSpec, TopologySpec, WeightSpec,
    };
    pub use crate::objective::{Objective, ScalarQuadratic};
    pub use crate::rng::Xoshiro256pp;
    pub use crate::state::{NodeRows, PlaneLayout, PlaneShard, StatePlane};
    pub use crate::stochastic::{
        DataPlane, SampleOracle, ShardLoss, ShardObjective, StochasticObjective,
    };
    pub use crate::telemetry::{PhaseTimers, Registry, TelemetrySummary};
    pub use crate::topology::Graph;
}
