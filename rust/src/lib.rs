//! # adcdgd — Amplified-Differential Compression DGD
//!
//! A production-grade reproduction of *"Compressed Distributed Gradient
//! Descent: Communication-Efficient Consensus over Networks"*
//! (Zhang, Liu, Zhu, Bentley; 2018).
//!
//! The library is a three-layer system:
//!
//! * **rust coordinator** (this crate): the decentralized-consensus
//!   runtime — topologies, consensus matrices, compression operators with
//!   exact wire-byte accounting, the algorithm family (DGD, DGD^t, naive
//!   compressed DGD, ADC-DGD, QDGD), a simulated network fabric, and the
//!   experiment harness regenerating every figure in the paper.
//! * **JAX models** (`python/compile/model.py`): ML objectives
//!   (logistic regression, transformer LM) AOT-lowered to HLO text.
//! * **Pallas kernels** (`python/compile/kernels/`): the compression and
//!   matmul hot-spots, checked against a pure-jnp oracle.
//!
//! The rust binary executes HLO artifacts through the PJRT C API (`xla`
//! crate) — python never runs on the request path.
//!
//! ## Example
//!
//! Solve the paper's four-node consensus problem with ADC-DGD
//! (`no_run`: rustdoc test binaries don't inherit the rpath to
//! `libxla_extension.so`; the same flow executes in
//! `examples/quickstart.rs` and the integration tests):
//!
//! ```no_run
//! use adcdgd::prelude::*;
//! use std::sync::Arc;
//!
//! let (graph, w) = paper_four_node_w();
//! let objectives = adcdgd::experiments::paper_four_node_objectives();
//! let cfg = RunConfig {
//!     iterations: 600,
//!     step_size: StepSize::Constant(0.02),
//!     record_every: 100,
//!     ..RunConfig::default()
//! };
//! let out = run_adc_dgd(
//!     &graph,
//!     &w,
//!     &objectives,
//!     Arc::new(RandomizedRounding::new()),
//!     &AdcDgdOptions { gamma: 1.0 },
//!     &cfg,
//! );
//! // Converges to the paper's optimum f* ≈ 0.292 while sending
//! // 2 B/element instead of DGD's 8.
//! assert!((out.metrics.objective.last().unwrap() - 0.292).abs() < 0.01);
//! ```

#![warn(missing_docs)]

pub mod algorithms;
pub mod compress;
pub mod experiments;
pub mod consensus;
pub mod coordinator;
pub mod engine;
pub mod linalg;
pub mod metrics;
pub mod network;
pub mod objective;
pub mod rng;
pub mod runtime;
pub mod topology;
pub mod util;

/// Convenient glob-import surface.
pub mod prelude {
    pub use crate::algorithms::{
        run_adc_dgd, run_dgd, run_dgd_t, run_naive_compressed, run_qdgd, AdcDgdOptions,
        CompressorRef, ObjectiveRef, QdgdOptions, StepSize,
    };
    pub use crate::compress::{
        Compressor, Identity, LowPrecisionQuantizer, Qsgd, QuantizationSparsifier,
        RandomizedRounding, TernGrad,
    };
    pub use crate::consensus::{metropolis, paper_four_node_w, ConsensusMatrix};
    pub use crate::coordinator::{EngineKind, RunConfig, RunOutput};
    pub use crate::objective::{Objective, ScalarQuadratic};
    pub use crate::rng::Xoshiro256pp;
    pub use crate::topology::Graph;
}
