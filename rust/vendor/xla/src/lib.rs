//! Inert offline stub of the `xla` crate (PJRT bindings).
//!
//! The container has no `libxla_extension` native library and no network
//! access, so this stub provides the exact API surface
//! `adcdgd::runtime` compiles against while reporting itself unavailable
//! at runtime: [`PjRtClient::cpu`] returns an error, which makes every
//! artifact-backed path self-skip (the integration tests and the `train`
//! subcommand already guard on artifact availability). Swapping this
//! path dependency for the real `xla` crate re-enables the PJRT runtime
//! without touching `adcdgd` source.

use std::borrow::Borrow;
use std::fmt;

/// Stub error: every fallible operation reports PJRT as unavailable.
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla::Error({})", self.0)
    }
}

/// Stub result type.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error("PJRT unavailable: offline xla stub (libxla_extension not present)".to_string())
}

/// Marker trait for element types a [`Literal`] can carry.
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for u64 {}

/// Stub PJRT client; construction always fails.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The real crate returns a CPU client; the stub reports
    /// unavailability (callers already handle this as "no artifacts").
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    /// Platform name (unreachable in practice: `cpu()` never succeeds).
    pub fn platform_name(&self) -> &'static str {
        "stub"
    }

    /// Platform version (unreachable in practice).
    pub fn platform_version(&self) -> &'static str {
        "0.0.0"
    }

    /// Device count (unreachable in practice).
    pub fn device_count(&self) -> usize {
        0
    }

    /// Compile a computation (unreachable in practice).
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Stub HLO module proto.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file; always fails in the stub.
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable())
    }
}

/// Stub XLA computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed proto.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

/// Stub compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given inputs; always fails in the stub.
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Stub device buffer.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Fetch the buffer to host; always fails in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Stub host literal. Construction succeeds (it is infallible in the
/// real crate) but every accessor fails.
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Self {
        Self { _private: () }
    }

    /// Build a rank-0 literal.
    pub fn scalar<T: NativeType>(_value: T) -> Self {
        Self { _private: () }
    }

    /// Reshape to the given dimensions; always fails in the stub.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    /// Read out the elements; always fails in the stub.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    /// Read the first element; always fails in the stub.
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        Err(unavailable())
    }

    /// Decompose a tuple literal; always fails in the stub.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err}").contains("unavailable"));
    }

    #[test]
    fn literal_construction_is_inert() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.to_vec::<f32>().is_err());
        assert!(Literal::scalar(1i32).get_first_element::<i32>().is_err());
    }
}
