//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! implements exactly the API subset the `adcdgd` workspace uses:
//! [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Errors carry a flattened
//! context chain; `{err}` prints the outermost message and `{err:#}`
//! prints the whole chain joined by `": "` (matching real-anyhow
//! alternate formatting).

use std::fmt;

/// A context-carrying error. `chain[0]` is the outermost message, later
/// entries are the underlying causes.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// Note: `Error` intentionally does NOT implement `std::error::Error`,
// exactly like real anyhow — that is what makes this blanket conversion
// coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// `Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    /// Attach a context message to the error (eager).
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Attach a context message to the error (lazy).
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)+) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] when the condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn failing() -> Result<()> {
        let io: std::io::Result<()> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "missing"));
        io.with_context(|| "reading file")?;
        Ok(())
    }

    #[test]
    fn context_chain_formats() {
        let err = failing().unwrap_err();
        assert_eq!(format!("{err}"), "reading file");
        assert!(format!("{err:#}").starts_with("reading file: missing"));
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let x = 3;
        let e = anyhow!("value {x} and {}", 4);
        assert_eq!(format!("{e}"), "value 3 and 4");
        fn bails() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(format!("{}", bails().unwrap_err()), "nope 1");
        fn ensures(v: usize) -> Result<usize> {
            ensure!(v > 2, "too small: {v}");
            Ok(v)
        }
        assert!(ensures(3).is_ok());
        assert_eq!(format!("{}", ensures(1).unwrap_err()), "too small: 1");
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let err = none.context("absent").unwrap_err();
        assert_eq!(format!("{err}"), "absent");
    }
}
