//! Quickstart: solve the paper's 4-node consensus problem with ADC-DGD
//! and compare against uncompressed DGD. Both runs are one
//! [`ScenarioSpec`] declaration each — no hand wiring.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use adcdgd::prelude::*;

fn main() {
    let cfg = RunConfig {
        iterations: 800,
        step_size: StepSize::Constant(0.02),
        record_every: 100,
        seed: 7,
        ..RunConfig::default()
    };

    // ADC-DGD: compressed amplified differentials (2 B/element int16).
    let adc_spec = ScenarioSpec::paper4(AlgorithmKind::AdcDgd(AdcDgdOptions { gamma: 1.0 }))
        .with_compressor(CompressorSpec::RandomizedRounding)
        .with_config(cfg);
    let prepared = adc_spec.prepare();
    // The paper's Fig. 3 network and Fig. 4 consensus matrix.
    println!(
        "network: N={} E={} beta={:.3}",
        prepared.graph().num_nodes(),
        prepared.graph().num_edges(),
        prepared.weights().beta()
    );
    let adc = prepared.run();
    // Uncompressed DGD (8 B/element f64).
    let dgd = run_scenario(&ScenarioSpec::paper4(AlgorithmKind::Dgd).with_config(cfg));

    println!("\n{:>8} {:>14} {:>14}", "round", "ADC-DGD f(x̄)", "DGD f(x̄)");
    for i in 0..adc.metrics.len() {
        println!(
            "{:>8} {:>14.6} {:>14.6}",
            adc.metrics.rounds[i], adc.metrics.objective[i], dgd.metrics.objective[i]
        );
    }
    println!(
        "\nfinal grad norm: ADC-DGD {:.3e} vs DGD {:.3e}",
        adc.metrics.grad_norm.last().unwrap(),
        dgd.metrics.grad_norm.last().unwrap()
    );
    println!(
        "bytes exchanged: ADC-DGD {} vs DGD {} ({:.1}x saving)",
        adc.total_bytes,
        dgd.total_bytes,
        dgd.total_bytes as f64 / adc.total_bytes as f64
    );
    // The paper's global optimum is x* = 0.06 (Σ aᵢbᵢ / Σ aᵢ).
    println!(
        "final states (→ 0.06): {:?}",
        adc.final_states.iter().map(|s| s[0]).collect::<Vec<_>>()
    );
}
