//! End-to-end decentralized training (DESIGN.md experiment E2E): a
//! byte-level GPT trained with ADC-DGD over a 4-node ring, gradients
//! computed by the AOT-compiled JAX/Pallas artifact through PJRT —
//! python is not involved at runtime.
//!
//! ```bash
//! make artifacts   # once
//! cargo run --release --example decentralized_training -- \
//!     --steps 300 --alpha 0.1 [--baseline-dgd] [--out curve.csv]
//! ```

use adcdgd::runtime::{artifacts_available, artifacts_dir, train_decentralized, TrainParams};
use adcdgd::util::args::Args;

fn main() {
    let args = Args::from_env().unwrap_or_default();
    let dir = artifacts_dir(args.options.get("artifacts").map(|s| s.as_str()));
    if !artifacts_available(&dir) {
        eprintln!(
            "artifacts not found in {} — run `make artifacts` first",
            dir.display()
        );
        std::process::exit(1);
    }
    let params = TrainParams {
        model: args.get_str("model", "transformer"),
        nodes: args.get::<usize>("nodes", 4).unwrap_or(4),
        steps: args.get::<usize>("steps", 300).unwrap_or(300),
        alpha: args.get::<f64>("alpha", 0.1).unwrap_or(0.1),
        gamma: args.get::<f64>("gamma", 1.0).unwrap_or(1.0),
        seed: args.get::<u64>("seed", 0).unwrap_or(0),
        compressor: args.get_str("compressor", "lowprec"),
        record_every: args.get::<usize>("record-every", 10).unwrap_or(10),
        baseline_dgd: args.has_flag("baseline-dgd"),
    };
    println!(
        "decentralized {} training: {} nodes (ring), {} rounds, α={}, γ={}, compressor={}",
        params.model, params.nodes, params.steps, params.alpha, params.gamma, params.compressor
    );
    match train_decentralized(&dir, &params) {
        Ok(report) => {
            println!("{}", report.render());
            if let Some(out) = args.options.get("out") {
                std::fs::write(out, report.to_csv()).expect("write csv");
                println!("loss curve -> {out}");
            }
            // Sanity: training must actually reduce the loss.
            let first = report.points.first().map(|p| p.loss).unwrap_or(f64::NAN);
            let last = report.points.last().map(|p| p.loss).unwrap_or(f64::NAN);
            assert!(last < first, "loss did not improve: {first} -> {last}");
            println!("ok: loss improved {first:.4} -> {last:.4}");
        }
        Err(e) => {
            eprintln!("training failed: {e:#}");
            std::process::exit(1);
        }
    }
}
