//! Distributed change-point detection over a sensor network — the
//! motivating application of paper §III-A.
//!
//! 12 sensors on a ring each observe a noisy copy of a common signal
//! with a step change. They reach consensus on the signal with ADC-DGD
//! (compressed, so each round costs 2 B/sample instead of 8) and then
//! locate the change point with the CUSUM statistic. The example also
//! shows detection still works with 10% message loss.
//!
//! ```bash
//! cargo run --release --example sensor_cusum
//! ```

use adcdgd::algorithms::ObjectiveRef;
use adcdgd::network::LinkModel;
use adcdgd::objective::{detect_change_point, CusumObjective};
use adcdgd::prelude::*;
use adcdgd::rng::Normal;
use std::sync::Arc;

fn main() {
    let n_sensors = 12;
    let t_len = 128;
    let true_cp = 80; // change-point index
    let mut rng = Xoshiro256pp::seed_from_u64(42);
    let noise = Normal::new(0.0, 0.8);

    // Ground-truth signal: 0 before the change, 1.5 after.
    let signal: Vec<f64> =
        (0..t_len).map(|t| if t >= true_cp { 1.5 } else { 0.0 }).collect();

    // Each sensor sees signal + heavy independent noise.
    let mut raw_series: Vec<Vec<f64>> = Vec::with_capacity(n_sensors);
    let objectives: Vec<ObjectiveRef> = (0..n_sensors)
        .map(|_| {
            let y: Vec<f64> = signal.iter().map(|&s| s + noise.sample(&mut rng)).collect();
            raw_series.push(y.clone());
            Arc::new(CusumObjective::new(y)) as ObjectiveRef
        })
        .collect();

    for drop_prob in [0.0, 0.10] {
        let cfg = RunConfig {
            iterations: 300,
            step_size: StepSize::Constant(0.2),
            record_every: 300,
            seed: 1,
            link: LinkModel { drop_prob, ..LinkModel::default() },
            ..RunConfig::default()
        };
        let spec = ScenarioSpec::new(
            AlgorithmKind::AdcDgd(AdcDgdOptions { gamma: 1.0 }),
            TopologySpec::Ring(n_sensors),
            ObjectiveSpec::Custom(objectives.clone()),
        )
        .with_compressor(CompressorSpec::LowPrecision { delta: 1.0 / 256.0 })
        .with_config(cfg);
        let out = run_scenario(&spec);
        // Consensus estimate = node 0's final state.
        let estimate = &out.final_states[0];
        let cp = detect_change_point(estimate);
        println!(
            "drop={drop_prob:>4}: detected change at t={cp} (truth {true_cp}), \
             consensus err {:.3e}, bytes {}, dropped {}",
            out.metrics.consensus_error.last().unwrap(),
            out.total_bytes,
            out.dropped_messages,
        );
        assert!((cp as i64 - true_cp as i64).abs() <= 3, "detection failed");
    }

    // Single-sensor baseline: CUSUM straight on one noisy series.
    let single_cp = detect_change_point(&raw_series[0]);
    println!("single-sensor CUSUM (no network): t={single_cp} (truth {true_cp})");
    println!("ok: network consensus sharpens noisy per-sensor detection");
}
