//! The γ phase transition (paper §IV-D) in one self-contained run:
//! sweep the amplification exponent, report iterations-to-accuracy and
//! the peak transmitted magnitude, and print the Fig. 7/8-style table.
//!
//! ```bash
//! cargo run --release --example gamma_sweep [-- --trials 20]
//! ```

use adcdgd::experiments::phase_transition;
use adcdgd::util::args::Args;

fn main() {
    let args = Args::from_env().unwrap_or_default();
    let mut p = phase_transition::Params::default();
    p.trials = args.get::<usize>("trials", 12).unwrap_or(12);
    p.iterations = args.get::<usize>("iters", 1500).unwrap_or(1500);

    println!(
        "gamma sweep on the paper 4-node network ({} trials, {} iters, threshold {}):\n",
        p.trials, p.iterations, p.threshold
    );
    let fr = phase_transition::run(&p);
    let iters = fr.series("iters_to_threshold").unwrap();
    let peak = fr.series("peak_transmitted").unwrap();
    println!("{:>6} {:>20} {:>18}", "gamma", "iters to ‖∇f̄‖<thr", "peak |k^γ·y|");
    for i in 0..iters.x.len() {
        let reached = iters.y[i] < 2.0 * p.iterations as f64;
        println!(
            "{:>6.2} {:>20} {:>18.2}",
            iters.x[i],
            if reached { format!("{:.0}", iters.y[i]) } else { "never".to_string() },
            peak.y[i],
        );
    }
    println!(
        "\nreading: convergence speed improves up to γ ≈ 1 and then saturates (the\n\
         paper's §IV-D phase transition); γ ≤ 1/2 violates the theory threshold\n\
         and is slow/noisy. On this scalar problem the transmitted magnitude is\n\
         dominated by the O(σ) compression-noise floor — its growth with γ shows\n\
         up in the transient (Fig. 8 reproduction, `cargo bench --bench\n\
         fig8_transmitted`) and in high-dimensional runs."
    );
}
