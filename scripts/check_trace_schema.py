#!/usr/bin/env python3
"""Validate a ``--trace out.jsonl`` run-trace file (schema v1).

The trace format is produced by ``adcdgd solve ... --trace out.jsonl``
(see ``rust/src/telemetry/trace.rs``):

* Line 1 — meta object: ``schema: "adcdgd-trace"``, ``version: 1``,
  ``rows`` (the data-line count), ``columns`` (the per-round column
  list), ``phases`` (the engine's phase table with accumulated wall
  seconds and span counts), and ``summary`` (the run's fleet counters).
* Lines 2.. — one object per recorded round, carrying exactly the
  declared columns, with strictly increasing ``round`` indices and
  non-decreasing cumulative byte columns.

The checker knows nothing about the scenario — it validates shape and
internal consistency only, so CI can run it on any sample trace.

Exit codes: 0 valid, 1 invalid, 2 usage error.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

EXPECTED_SCHEMA = "adcdgd-trace"
EXPECTED_VERSION = 1
EXPECTED_COLUMNS = [
    "round",
    "grad_iterations",
    "objective",
    "grad_norm",
    "consensus_error",
    "bytes_cumulative",
    "measured_bytes_cumulative",
    "max_transmitted",
    "saturations",
]
SUMMARY_FIELDS = (
    "enabled", "sends", "drops", "superseded", "straggler_delayed",
    "modeled_bytes", "measured_bytes", "fresh_payload_cells",
    "total_phase_secs",
)
PHASE_FIELDS = ("name", "total_secs", "count")


def fail(msg: str) -> None:
    sys.exit(f"trace invalid: {msg}")


def check(path: Path) -> None:
    try:
        lines = path.read_text().splitlines()
    except OSError as e:
        sys.exit(f"error: cannot read {path}: {e}")
    if not lines:
        fail("empty file (expected a meta line)")
    try:
        meta = json.loads(lines[0])
    except json.JSONDecodeError as e:
        fail(f"meta line is not JSON: {e}")

    if meta.get("schema") != EXPECTED_SCHEMA:
        fail(f"schema {meta.get('schema')!r}, expected {EXPECTED_SCHEMA!r}")
    if meta.get("version") != EXPECTED_VERSION:
        fail(f"version {meta.get('version')!r}, expected {EXPECTED_VERSION}")
    columns = meta.get("columns")
    if columns != EXPECTED_COLUMNS:
        fail(f"columns {columns!r}, expected {EXPECTED_COLUMNS!r}")
    rows = meta.get("rows")
    data_lines = lines[1:]
    if rows != len(data_lines):
        fail(f"meta declares {rows} rows, file has {len(data_lines)}")
    for i, phase in enumerate(meta.get("phases", [])):
        for field in PHASE_FIELDS:
            if field not in phase:
                fail(f"phase entry {i} missing {field!r}: {phase!r}")
        if phase["total_secs"] < 0 or phase["count"] < 0:
            fail(f"phase entry {i} has negative stats: {phase!r}")
    summary = meta.get("summary")
    if not isinstance(summary, dict):
        fail("meta has no summary object")
    for field in SUMMARY_FIELDS:
        if field not in summary:
            fail(f"summary missing {field!r}")

    prev_round = 0
    prev_bytes = -1
    prev_measured = -1
    for i, line in enumerate(data_lines, start=2):
        try:
            row = json.loads(line)
        except json.JSONDecodeError as e:
            fail(f"line {i} is not JSON: {e}")
        extra = set(row) - set(EXPECTED_COLUMNS)
        missing = set(EXPECTED_COLUMNS) - set(row)
        if extra or missing:
            fail(f"line {i} columns mismatch (missing {sorted(missing)}, "
                 f"extra {sorted(extra)})")
        if row["round"] <= prev_round:
            fail(f"line {i}: round {row['round']} not strictly increasing "
                 f"(previous {prev_round})")
        prev_round = row["round"]
        if row["bytes_cumulative"] < prev_bytes:
            fail(f"line {i}: bytes_cumulative decreased")
        prev_bytes = row["bytes_cumulative"]
        if row["measured_bytes_cumulative"] < prev_measured:
            fail(f"line {i}: measured_bytes_cumulative decreased")
        prev_measured = row["measured_bytes_cumulative"]
    # The final cumulative totals must agree with the summary counters
    # (only meaningful when the run had telemetry on — with
    # --no-telemetry the summary is all zeros by contract).
    if data_lines and summary["enabled"]:
        last = json.loads(data_lines[-1])
        if last["bytes_cumulative"] != summary["modeled_bytes"]:
            fail(f"final bytes_cumulative {last['bytes_cumulative']} != "
                 f"summary modeled_bytes {summary['modeled_bytes']}")
        if last["measured_bytes_cumulative"] != summary["measured_bytes"]:
            fail(f"final measured_bytes_cumulative "
                 f"{last['measured_bytes_cumulative']} != summary "
                 f"measured_bytes {summary['measured_bytes']}")
    print(f"{path}: valid adcdgd-trace v{EXPECTED_VERSION} "
          f"({len(data_lines)} rounds, {len(meta.get('phases', []))} phases)")


def main() -> int:
    if len(sys.argv) != 2:
        print(f"usage: {sys.argv[0]} <trace.jsonl>", file=sys.stderr)
        return 2
    check(Path(sys.argv[1]))
    return 0


if __name__ == "__main__":
    sys.exit(main())
