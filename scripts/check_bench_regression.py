#!/usr/bin/env python3
"""Gate dimension-plane benchmark throughput against a committed baseline.

Compares the freshly produced ``BENCH_dim_plane.json`` (written by
``ADCDGD_BENCH_ONLY=dim cargo bench --bench hotpath``) against the
snapshot committed under ``BENCH_baseline/``. The gate fails when any
(n, p, tiles) configuration regresses by more than the allowed margin
(default: rounds/sec below 75% of baseline, i.e. a >25% regression), or
when a baseline configuration disappeared from the current run.

Modes:

* Baseline missing  -> bootstrap: pass, and print the command that
  records one. CI stays green until a baseline is deliberately
  committed; numbers are never invented here.
* ``--update``      -> copy the current JSON into ``BENCH_baseline/``
  (run on a quiet, representative machine, then commit the result).

Exit codes: 0 pass / bootstrap, 1 regression, 2 usage or parse error.
"""

from __future__ import annotations

import argparse
import json
import shutil
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
DEFAULT_CURRENT = REPO_ROOT / "BENCH_dim_plane.json"
DEFAULT_BASELINE = REPO_ROOT / "BENCH_baseline" / "BENCH_dim_plane.json"
# A configuration fails when current rounds/sec drops below this
# fraction of the baseline (0.75 => >25% regression fails).
DEFAULT_THRESHOLD = 0.75


def load_results(path: Path) -> dict[tuple[int, int, int], dict]:
    """Index a bench JSON's result rows by (n, p, tiles)."""
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        sys.exit(f"error: cannot read {path}: {e}")
    rows = doc.get("results")
    if not isinstance(rows, list) or not rows:
        sys.exit(f"error: {path} has no 'results' rows")
    indexed = {}
    for row in rows:
        try:
            key = (int(row["n"]), int(row["p"]), int(row["tiles"]))
            float(row["rounds_per_sec"])
        except (KeyError, TypeError, ValueError) as e:
            sys.exit(f"error: malformed result row in {path}: {row!r} ({e})")
        indexed[key] = row
    return indexed


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", type=Path, default=DEFAULT_CURRENT,
                    help="bench JSON produced by the current run")
    ap.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                    help="committed baseline JSON to compare against")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="minimum allowed current/baseline rounds/sec ratio")
    ap.add_argument("--update", action="store_true",
                    help="record the current JSON as the new baseline")
    args = ap.parse_args()

    if not args.current.exists():
        sys.exit(f"error: {args.current} not found — run "
                 "ADCDGD_BENCH_ONLY=dim cargo bench --bench hotpath first")
    current = load_results(args.current)

    if args.update:
        args.baseline.parent.mkdir(parents=True, exist_ok=True)
        shutil.copyfile(args.current, args.baseline)
        print(f"baseline updated: {args.baseline} "
              f"({len(current)} configurations)")
        return 0

    if not args.baseline.exists():
        print(f"no baseline at {args.baseline} — bootstrap pass.")
        print("record one on a quiet, representative machine with:")
        print("  ADCDGD_BENCH_ONLY=dim cargo bench --bench hotpath")
        print("  python3 scripts/check_bench_regression.py --update")
        return 0

    baseline = load_results(args.baseline)
    failures = []
    for key, base_row in sorted(baseline.items()):
        n, p, tiles = key
        label = f"n={n} p={p} tiles={tiles}"
        cur_row = current.get(key)
        if cur_row is None:
            failures.append(f"{label}: configuration missing from current run")
            continue
        base_rps = float(base_row["rounds_per_sec"])
        cur_rps = float(cur_row["rounds_per_sec"])
        ratio = cur_rps / base_rps if base_rps > 0 else float("inf")
        verdict = "ok" if ratio >= args.threshold else "REGRESSION"
        print(f"{label}: {cur_rps:.2f} vs baseline {base_rps:.2f} rounds/s "
              f"(x{ratio:.3f}) {verdict}")
        if ratio < args.threshold:
            failures.append(
                f"{label}: {cur_rps:.2f} rounds/s is below "
                f"{args.threshold:.0%} of baseline {base_rps:.2f}")
    for key in sorted(set(current) - set(baseline)):
        n, p, tiles = key
        print(f"n={n} p={p} tiles={tiles}: new configuration (no baseline)")

    if failures:
        print(f"\n{len(failures)} regression(s) beyond the "
              f"{1 - args.threshold:.0%} margin:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("dim-plane throughput within margin of baseline.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
