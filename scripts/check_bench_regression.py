#!/usr/bin/env python3
"""Gate benchmark throughput against the committed ``BENCH_baseline/``.

Every ``BENCH_*.json`` plane with a snapshot under ``BENCH_baseline/``
is gated: the freshly produced JSON in the repo root (written by the
``ADCDGD_BENCH_ONLY=<section> cargo bench --bench hotpath`` runs) is
compared row by row against its baseline. A row is identified by its
shape fields (n, p, tiles, wire, ...; machine-dependent fields such as
worker counts are excluded), and every metric in it is checked:

* ``rounds_per_sec`` — higher is better; fails below ``threshold``
  times the baseline (default 0.75, i.e. a >25% regression).
* ``*_mean_s`` — lower is better; fails when the baseline-to-current
  ratio drops below the same threshold.

Speedup ratios and allocation counters are not gated here (the
allocation contracts are hard ``assert_eq!(allocs, 0)`` in the bench
binary itself).

Modes:

* Baseline missing entirely -> bootstrap: pass, and print the command
  that records one. CI stays green until a baseline is deliberately
  committed; numbers are never invented here.
* Baseline present for a plane whose current JSON is absent -> that
  plane is reported and skipped (the gate only judges what this run
  produced).
* ``--update``             -> copy every current ``BENCH_*.json`` into
  ``BENCH_baseline/`` (run on a quiet, representative machine, then
  commit the result).
* ``--current/--baseline`` -> legacy single-pair mode, unchanged.

Exit codes: 0 pass / bootstrap, 1 regression, 2 usage or parse error.
"""

from __future__ import annotations

import argparse
import json
import shutil
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
BASELINE_DIR = REPO_ROOT / "BENCH_baseline"
# A metric fails when its better-is-higher ratio drops below this
# fraction of the baseline (0.75 => >25% regression fails).
DEFAULT_THRESHOLD = 0.75

# Row-shape fields: stable identifiers of a configuration. Anything
# machine-dependent (pool_workers, workers, machine_parallelism) must
# stay out, or a baseline recorded on one box can never match another.
KEY_FIELDS = (
    "n", "p", "dim", "tiles", "wire", "rounds", "timed_rounds", "shard",
    "batch", "edges", "k_regular", "epoch_len", "epochs", "churn_per_epoch",
    "telemetry",
)


def row_key(row: dict) -> tuple:
    return tuple((f, row[f]) for f in KEY_FIELDS if f in row)


def row_label(key: tuple) -> str:
    return " ".join(f"{f}={v}" for f, v in key) or "(single row)"


def row_metrics(row: dict) -> dict[str, tuple[float, bool]]:
    """Gated metrics of a row: name -> (value, higher_is_better)."""
    out = {}
    for name, value in row.items():
        if name == "rounds_per_sec":
            out[name] = (float(value), True)
        elif name.endswith("_mean_s"):
            out[name] = (float(value), False)
    return out


def load_results(path: Path) -> dict[tuple, dict]:
    """Index a bench JSON's result rows by their shape fields."""
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        sys.exit(f"error: cannot read {path}: {e}")
    rows = doc.get("results")
    if not isinstance(rows, list) or not rows:
        sys.exit(f"error: {path} has no 'results' rows")
    indexed = {}
    for row in rows:
        try:
            key = row_key(row)
            if not row_metrics(row):
                raise ValueError("no gatable metric")
        except (KeyError, TypeError, ValueError) as e:
            sys.exit(f"error: malformed result row in {path}: {row!r} ({e})")
        indexed[key] = row
    return indexed


def gate_pair(current_path: Path, baseline_path: Path,
              threshold: float) -> list[str]:
    """Compare one plane; returns the failure messages (empty = pass)."""
    plane = current_path.name
    current = load_results(current_path)
    baseline = load_results(baseline_path)
    failures = []
    for key, base_row in sorted(baseline.items()):
        label = f"{plane} {row_label(key)}"
        cur_row = current.get(key)
        if cur_row is None:
            failures.append(f"{label}: configuration missing from current run")
            continue
        cur_metrics = row_metrics(cur_row)
        for name, (base_val, higher_better) in sorted(
                row_metrics(base_row).items()):
            if name not in cur_metrics:
                failures.append(f"{label}: metric {name} missing")
                continue
            cur_val = cur_metrics[name][0]
            if higher_better:
                ratio = cur_val / base_val if base_val > 0 else float("inf")
            else:
                ratio = base_val / cur_val if cur_val > 0 else float("inf")
            verdict = "ok" if ratio >= threshold else "REGRESSION"
            print(f"{label} {name}: {cur_val:.4g} vs baseline "
                  f"{base_val:.4g} (x{ratio:.3f}) {verdict}")
            if ratio < threshold:
                failures.append(
                    f"{label}: {name} {cur_val:.4g} is beyond the "
                    f"{1 - threshold:.0%} margin of baseline {base_val:.4g}")
    for key in sorted(set(current) - set(baseline)):
        print(f"{plane} {row_label(key)}: new configuration (no baseline)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", type=Path, default=None,
                    help="gate one bench JSON instead of every plane")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="baseline JSON for --current (default: the "
                         "same file name under BENCH_baseline/)")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="minimum allowed current/baseline metric ratio")
    ap.add_argument("--update", action="store_true",
                    help="record the current JSON(s) as the new baseline")
    args = ap.parse_args()

    # Legacy single-pair mode.
    if args.current is not None:
        baseline = args.baseline or BASELINE_DIR / args.current.name
        if not args.current.exists():
            sys.exit(f"error: {args.current} not found — run the matching "
                     "ADCDGD_BENCH_ONLY=<section> cargo bench first")
        if args.update:
            baseline.parent.mkdir(parents=True, exist_ok=True)
            shutil.copyfile(args.current, baseline)
            print(f"baseline updated: {baseline} "
                  f"({len(load_results(args.current))} configurations)")
            return 0
        if not baseline.exists():
            print(f"no baseline at {baseline} — bootstrap pass.")
            return 0
        failures = gate_pair(args.current, baseline, args.threshold)
        return report(failures, args.threshold)

    # Fleet mode: every BENCH_*.json plane.
    current_planes = sorted(REPO_ROOT.glob("BENCH_*.json"))
    if args.update:
        if not current_planes:
            sys.exit("error: no BENCH_*.json in the repo root — run the "
                     "bench sections first")
        BASELINE_DIR.mkdir(parents=True, exist_ok=True)
        for path in current_planes:
            shutil.copyfile(path, BASELINE_DIR / path.name)
            print(f"baseline updated: {BASELINE_DIR / path.name}")
        return 0

    baselines = sorted(BASELINE_DIR.glob("BENCH_*.json"))
    if not baselines:
        print(f"no baselines under {BASELINE_DIR} — bootstrap pass.")
        print("record them on a quiet, representative machine with:")
        print("  ADCDGD_BENCH_ONLY=<section> cargo bench --bench hotpath")
        print("  python3 scripts/check_bench_regression.py --update")
        return 0

    failures = []
    for baseline in baselines:
        current = REPO_ROOT / baseline.name
        if not current.exists():
            print(f"{baseline.name}: not produced by this run — skipped")
            continue
        failures += gate_pair(current, baseline, args.threshold)
    return report(failures, args.threshold)


def report(failures: list[str], threshold: float) -> int:
    if failures:
        print(f"\n{len(failures)} regression(s) beyond the "
              f"{1 - threshold:.0%} margin:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("bench throughput within margin of baseline.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
