"""AOT lowering: JAX/Pallas → HLO **text** artifacts + manifest.

Interchange is HLO text, not serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids which the rust side's xla_extension
0.5.1 rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Artifacts written to ``--out`` (default ``../artifacts``):

* ``quad.hlo.txt``          — (x, a, b) → (value, grad)            [P = 4]
* ``logistic.hlo.txt``      — (w, X, y, lam) → (loss, grad)        [M = 64, D = 16]
* ``transformer.hlo.txt``   — (*params, tokens) → (loss, *grads)
* ``transformer_params.bin``— initial parameters, flat f32 little-endian
* ``quantize.hlo.txt``      — (y, u, k_gamma) → C(k^γ y)           [P = 65536]
* ``consensus.hlo.txt``     — (X, w, g, alpha) → wᵀX − αg          [N = 4, P = 4096]
* ``manifest.json``         — shapes/dtypes/params contract for rust

Run once at build time (``make artifacts``); never on the request path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import consensus as consensus_kernel
from .kernels import quantize as quantize_kernel


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the
    rust side always unwraps a tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.int32 if dtype == "s32" else jnp.float32)


def io_entry(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def build_quad(out_dir, p=4):
    def fn(x, a, b):
        return model.quad_value_and_grad(x, a, b)

    lowered = jax.jit(fn).lower(spec([p]), spec([p]), spec([p]))
    path = os.path.join(out_dir, "quad.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    return {
        "hlo": "quad.hlo.txt",
        "inputs": [io_entry("x", [p]), io_entry("a", [p]), io_entry("b", [p])],
        "outputs": [io_entry("value", []), io_entry("grad", [p])],
        "meta": {"p": p},
    }


def build_logistic(out_dir, m=64, d=16):
    def fn(w, features, labels, lam):
        return model.logistic_value_and_grad(w, features, labels, lam)

    lowered = jax.jit(fn).lower(spec([d]), spec([m, d]), spec([m]), spec([]))
    path = os.path.join(out_dir, "logistic.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    return {
        "hlo": "logistic.hlo.txt",
        "inputs": [
            io_entry("w", [d]),
            io_entry("features", [m, d]),
            io_entry("labels", [m]),
            io_entry("lam", []),
        ],
        "outputs": [io_entry("loss", []), io_entry("grad", [d])],
        "meta": {"m": m, "d": d},
    }


def build_transformer(out_dir, cfg: model.TransformerConfig, seed=0):
    specs = model.param_specs(cfg)

    def fn(*args):
        flat_params = args[:-1]
        tokens = args[-1]
        return model.transformer_loss_and_grads(list(flat_params), tokens, cfg)

    in_specs = [spec(shape) for _, shape, _ in specs]
    in_specs.append(spec([cfg.batch, cfg.seq_len + 1], "s32"))
    lowered = jax.jit(fn).lower(*in_specs)
    with open(os.path.join(out_dir, "transformer.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    # Initial parameters, concatenated flat f32 LE in spec order.
    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    flat = np.concatenate(
        [np.asarray(params[name], np.float32).reshape(-1) for name, _, _ in specs]
    )
    flat.tofile(os.path.join(out_dir, "transformer_params.bin"))

    inputs = [io_entry(name, shape) for name, shape, _ in specs]
    inputs.append(io_entry("tokens", [cfg.batch, cfg.seq_len + 1], "s32"))
    outputs = [io_entry("loss", [])]
    outputs += [io_entry("d_" + name, shape) for name, shape, _ in specs]
    return {
        "hlo": "transformer.hlo.txt",
        "inputs": inputs,
        "outputs": outputs,
        "params": {
            "file": "transformer_params.bin",
            "count": len(specs),
            "total": int(flat.size),
        },
        "meta": {
            "vocab": cfg.vocab,
            "seq_len": cfg.seq_len,
            "d_model": cfg.d_model,
            "n_head": cfg.n_head,
            "n_layer": cfg.n_layer,
            "d_mlp": cfg.d_mlp,
            "batch": cfg.batch,
        },
    }


def build_quantize(out_dir, p=65536):
    def fn(y, u, kg):
        return (quantize_kernel.amplified_round(y, u, kg),)

    lowered = jax.jit(fn).lower(spec([p]), spec([p]), spec([]))
    with open(os.path.join(out_dir, "quantize.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    return {
        "hlo": "quantize.hlo.txt",
        "inputs": [io_entry("y", [p]), io_entry("u", [p]), io_entry("k_gamma", [])],
        "outputs": [io_entry("q", [p])],
        "meta": {"p": p},
    }


def build_consensus(out_dir, n=4, p=4096):
    def fn(x_stack, w, g, alpha):
        return (consensus_kernel.consensus_step(x_stack, w, g, alpha),)

    lowered = jax.jit(fn).lower(spec([n, p]), spec([n]), spec([p]), spec([]))
    with open(os.path.join(out_dir, "consensus.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    return {
        "hlo": "consensus.hlo.txt",
        "inputs": [
            io_entry("x_stack", [n, p]),
            io_entry("w", [n]),
            io_entry("g", [p]),
            io_entry("alpha", []),
        ],
        "outputs": [io_entry("out", [p])],
        "meta": {"n": n, "p": p},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-layer", type=int, default=2)
    ap.add_argument("--n-head", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = model.TransformerConfig(
        d_model=args.d_model,
        n_layer=args.n_layer,
        n_head=args.n_head,
        seq_len=args.seq_len,
        d_mlp=4 * args.d_model,
        batch=args.batch,
    )
    manifest = {
        "format_version": 1,
        "models": {
            "quad": build_quad(args.out),
            "logistic": build_logistic(args.out),
            "transformer": build_transformer(args.out, cfg, args.seed),
            "quantize": build_quantize(args.out),
            "consensus": build_consensus(args.out),
        },
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    sizes = {
        name: os.path.getsize(os.path.join(args.out, m["hlo"]))
        for name, m in manifest["models"].items()
    }
    print(f"artifacts written to {args.out}: " + ", ".join(f"{k}={v}B" for k, v in sizes.items()))


if __name__ == "__main__":
    main()
