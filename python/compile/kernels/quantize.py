"""Stochastic-rounding quantization kernel — the ADC-DGD compression
hot-spot (paper Def. 1 / Example 2).

The kernel is *pure*: the uniform noise ``u ~ U[0,1)`` is an explicit
input tensor rather than an in-kernel PRNG, so (a) the kernel is exactly
checkable against :func:`ref.stochastic_round_ref`, and (b) the host
controls the randomness stream (rust's xoshiro feeds the same noise to
the AOT'd kernel when using the ``XlaQuantizer`` backend).

TPU mapping (DESIGN.md §5): elementwise over P, tiled into
``BLOCK``-sized VMEM blocks via a 1-D grid; on real hardware the grid
double-buffers HBM→VMEM automatically. The op intensity is O(1)
flops/byte — memory-bound — so block size only needs to cover DMA
latency; 4096 f32 = 16 KiB per ref, far under VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096


def _quantize_kernel(z_ref, u_ref, o_ref):
    z = z_ref[...]
    lo = jnp.floor(z)
    frac = z - lo
    o_ref[...] = lo + (u_ref[...] < frac).astype(z.dtype)


@functools.partial(jax.jit, static_argnames=("block",))
def stochastic_round(z, u, block=BLOCK):
    """Stochastically round ``z`` to integers using uniform noise ``u``.

    Unbiased: ``E[out] = z`` because ``P(round up) = frac(z)``.
    Shapes: ``z`` and ``u`` are rank-1 of equal length; any length is
    accepted (padded internally to a block multiple).
    """
    assert z.ndim == 1 and z.shape == u.shape, (z.shape, u.shape)
    p = z.shape[0]
    block = min(block, max(p, 1))
    padded = (p + block - 1) // block * block
    zp = jnp.pad(z, (0, padded - p))
    # Pad noise with 1.0 so padding never rounds up (stays exactly 0).
    up = jnp.pad(u, (0, padded - p), constant_values=1.0)
    out = pl.pallas_call(
        _quantize_kernel,
        out_shape=jax.ShapeDtypeStruct((padded,), z.dtype),
        grid=(padded // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(zp, up)
    return out[:p]


def _amplified_kernel(y_ref, u_ref, kg_ref, o_ref):
    """Fused amplify + stochastic round: round(k^γ · y) in one pass."""
    z = y_ref[...] * kg_ref[0]
    lo = jnp.floor(z)
    frac = z - lo
    o_ref[...] = lo + (u_ref[...] < frac).astype(z.dtype)


@functools.partial(jax.jit, static_argnames=("block",))
def amplified_round(y, u, k_gamma, block=BLOCK):
    """ADC-DGD's transmit transform ``C(k^γ y)`` fused into one kernel.

    ``k_gamma`` is a scalar (traced, so one compiled artifact serves all
    rounds).
    """
    assert y.ndim == 1 and y.shape == u.shape
    p = y.shape[0]
    block = min(block, max(p, 1))
    padded = (p + block - 1) // block * block
    yp = jnp.pad(y, (0, padded - p))
    up = jnp.pad(u, (0, padded - p), constant_values=1.0)
    kg = jnp.asarray(k_gamma, dtype=y.dtype).reshape((1,))
    out = pl.pallas_call(
        _amplified_kernel,
        out_shape=jax.ShapeDtypeStruct((padded,), y.dtype),
        grid=(padded // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(yp, up, kg)
    return out[:p]
