"""Tiled matmul (+ bias + GELU) kernel — the transformer's MLP/projection
hot-spot — with custom VJPs so the L2 model can differentiate through it
(`pallas_call` has no built-in transpose rule; the backward passes are
themselves Pallas matmuls: dA = dC·Bᵀ, dB = Aᵀ·dC).

TPU mapping (DESIGN.md §5): 2-D grid over (M/bm, N/bn) output tiles with
the full K dimension resident per tile (model dims here are ≤ 512, so a
``bm×K`` + ``K×bn`` slab fits VMEM comfortably); the inner ``jnp.dot``
maps onto the MXU systolic array. ``preferred_element_type=float32``
keeps the accumulator in f32 — the paper-era GPU fp32-accumulate GEMM
translated to TPU idiom. The GELU epilogue is fused into the forward
kernel; the backward rematerializes the pre-activation (one extra
matmul) — the standard remat trade.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 128
BN = 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def _matmul_bias_gelu_kernel(a_ref, b_ref, bias_ref, o_ref):
    acc = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    acc = acc + bias_ref[...]
    o_ref[...] = jax.nn.gelu(acc)


def _matmul_bias_kernel(a_ref, b_ref, bias_ref, o_ref):
    acc = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = acc + bias_ref[...]


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def _matmul_impl(a, b, bm=BM, bn=BN):
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm = min(bm, m)
    bn = min(bn, n)
    ap = _pad_to(a, bm, 1)
    bp = _pad_to(b, 1, bn)
    mp, np_ = ap.shape[0], bp.shape[1]
    out = pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("gelu", "bm", "bn"))
def _matmul_bias_impl(a, b, bias, gelu=False, bm=BM, bn=BN):
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and bias.shape == (n,), (a.shape, b.shape, bias.shape)
    bm = min(bm, m)
    bn = min(bn, n)
    ap = _pad_to(a, bm, 1)
    bp = _pad_to(b, 1, bn)
    biasp = jnp.pad(bias, (0, bp.shape[1] - n))
    mp, np_ = ap.shape[0], bp.shape[1]
    kernel = _matmul_bias_gelu_kernel if gelu else _matmul_bias_kernel
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(ap, bp, biasp)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# Differentiable wrappers
# ---------------------------------------------------------------------------

@jax.custom_vjp
def matmul(a, b):
    """Differentiable Pallas ``a @ b``."""
    return _matmul_impl(a, b)


def _matmul_fwd(a, b):
    return _matmul_impl(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    return _matmul_impl(g, b.T), _matmul_impl(a.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def matmul_bias(a, b, bias, gelu=False):
    """Differentiable Pallas ``a @ b + bias`` with optional fused GELU."""
    return _matmul_bias_impl(a, b, bias, gelu=gelu)


def _matmul_bias_fwd(a, b, bias, gelu):
    return _matmul_bias_impl(a, b, bias, gelu=gelu), (a, b, bias)


def _matmul_bias_bwd(gelu, res, g):
    a, b, bias = res
    if gelu:
        # Rematerialize the pre-activation, then chain through GELU.
        z = _matmul_bias_impl(a, b, bias, gelu=False)
        _, gelu_vjp = jax.vjp(jax.nn.gelu, z)
        (dz,) = gelu_vjp(g)
    else:
        dz = g
    da = _matmul_impl(dz, b.T)
    db = _matmul_impl(a.T, dz)
    dbias = jnp.sum(dz, axis=0)
    return da, db, dbias


matmul_bias.defvjp(_matmul_bias_fwd, _matmul_bias_bwd)
