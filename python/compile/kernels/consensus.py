"""Fused consensus + gradient-step kernel.

Computes one node's DGD/ADC-DGD inner update for high-dimensional
states: ``out = wᵀ X − α g`` where ``X ∈ R^{N×P}`` stacks the (mirror)
states of the node's closed neighborhood, ``w ∈ R^N`` is its mixing-
weight row, and ``g ∈ R^P`` its local gradient.

TPU mapping: P is tiled into VMEM blocks; each grid step holds the full
``N × block`` neighbor slab resident (N is a node degree — small), so
the reduction over N is a cheap VPU axis-0 sum, and HBM traffic is the
N+2 streamed vectors — the kernel is bandwidth-bound by design, exactly
like the original update.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 2048


def _consensus_kernel(x_ref, w_ref, g_ref, alpha_ref, o_ref):
    x = x_ref[...]  # (N, block)
    w = w_ref[...]  # (N,)
    mix = jnp.sum(x * w[:, None], axis=0)
    o_ref[...] = mix - alpha_ref[0] * g_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def consensus_step(x_stack, w, g, alpha, block=BLOCK):
    """``wᵀ x_stack − α g`` with P tiled into ``block`` chunks."""
    n, p = x_stack.shape
    assert w.shape == (n,), (w.shape, n)
    assert g.shape == (p,), (g.shape, p)
    block = min(block, max(p, 1))
    padded = (p + block - 1) // block * block
    xp = jnp.pad(x_stack, ((0, 0), (0, padded - p)))
    gp = jnp.pad(g, (0, padded - p))
    a = jnp.asarray(alpha, dtype=x_stack.dtype).reshape((1,))
    out = pl.pallas_call(
        _consensus_kernel,
        out_shape=jax.ShapeDtypeStruct((padded,), x_stack.dtype),
        grid=(padded // block,),
        in_specs=[
            pl.BlockSpec((n, block), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(xp, w, gp, a)
    return out[:p]
