"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO).

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is the correctness path
and real-TPU performance is *estimated* from the BlockSpec schedule
(DESIGN.md §5). Every kernel has a pure-jnp oracle in :mod:`ref` checked
by pytest + hypothesis.
"""

from . import consensus, matmul, quantize, ref  # noqa: F401
