"""Pure-jnp oracles for every Pallas kernel — the correctness ground
truth enforced by pytest + hypothesis (``tests/test_kernels.py``)."""

import jax
import jax.numpy as jnp


def stochastic_round_ref(z, u):
    """Reference for :func:`quantize.stochastic_round`."""
    lo = jnp.floor(z)
    return lo + (u < (z - lo)).astype(z.dtype)


def amplified_round_ref(y, u, k_gamma):
    """Reference for :func:`quantize.amplified_round`."""
    return stochastic_round_ref(y * k_gamma, u)


def consensus_step_ref(x_stack, w, g, alpha):
    """Reference for :func:`consensus.consensus_step`."""
    return w @ x_stack - alpha * g


def matmul_ref(a, b):
    """Reference for :func:`matmul.matmul`."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def matmul_bias_ref(a, b, bias, gelu=False):
    """Reference for :func:`matmul.matmul_bias`."""
    out = jnp.dot(a, b, preferred_element_type=jnp.float32) + bias
    return jax.nn.gelu(out) if gelu else out
