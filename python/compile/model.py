"""Layer-2 JAX models: each node's local objective as a (value, grad)
computation, calling the L1 Pallas kernels, AOT-lowered by ``aot.py``.

Three model families:

* :func:`quad_value_and_grad` — the paper's scalar-quadratic family
  vectorized (cross-checks the rust-native objective through the PJRT
  path).
* :func:`logistic_value_and_grad` — L2-regularized logistic regression
  (deterministic given the node's data shard; cross-checked against the
  pure-rust implementation to 1e-5).
* :class:`TransformerConfig` / :func:`transformer_loss_and_grads` — a
  byte-level GPT used by the decentralized-training E2E example: causal
  self-attention, Pallas fused-matmul MLP, weight-tied LM head.

Everything is f32 (the PJRT CPU path; rust converts its f64 state).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import matmul


# --------------------------------------------------------------------------
# Quadratics: f(x) = sum_j a_j (x_j - b_j)^2
# --------------------------------------------------------------------------

def quad_value_and_grad(x, a, b):
    """Value and gradient of ``Σ a·(x−b)²`` (elementwise a, b)."""
    d = x - b
    value = jnp.sum(a * d * d)
    grad = 2.0 * a * d
    return value, grad


# --------------------------------------------------------------------------
# Logistic regression: mean log-loss + (lam/2)||w||^2, labels in {-1,+1}
# --------------------------------------------------------------------------

def logistic_value_and_grad(w, features, labels, lam):
    """Stable value+grad of L2-regularized logistic regression.

    The logit matvec goes through the Pallas matmul kernel so the L1
    layer sits on the model's hot path.
    """
    logits = matmul.matmul(features, w[:, None])[:, 0]
    margins = labels * logits
    # log(1 + e^{-m}) stably
    loss = jnp.mean(jnp.logaddexp(0.0, -margins)) + 0.5 * lam * jnp.sum(w * w)
    sig = jax.nn.sigmoid(-margins)  # = 1/(1+e^{m})
    coef = -labels * sig / labels.shape[0]
    grad = matmul.matmul(coef[None, :], features)[0] + lam * w
    return loss, grad


# --------------------------------------------------------------------------
# Byte-level GPT
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    """Model shape. Defaults give ≈0.44 M parameters (CPU-friendly);
    scale ``d_model``/``n_layer`` up for larger runs."""

    vocab: int = 256
    seq_len: int = 64
    d_model: int = 128
    n_head: int = 4
    n_layer: int = 2
    d_mlp: int = 512
    batch: int = 8

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_head == 0
        return self.d_model // self.n_head


def param_specs(cfg: TransformerConfig) -> List[Tuple[str, Tuple[int, ...], float]]:
    """Ordered (name, shape, init_std) list — the flattening contract
    shared with the rust runtime via the manifest."""
    d, v, t, m = cfg.d_model, cfg.vocab, cfg.seq_len, cfg.d_mlp
    specs: List[Tuple[str, Tuple[int, ...], float]] = [
        ("wte", (v, d), 0.02),
        ("wpe", (t, d), 0.02),
    ]
    proj_std = 0.02 / (2.0 * cfg.n_layer) ** 0.5  # GPT-2 style residual scaling
    for layer in range(cfg.n_layer):
        pre = f"h{layer}."
        specs += [
            (pre + "ln1_g", (d,), -1.0),  # std<0 ⇒ init to ones
            (pre + "ln1_b", (d,), 0.0),
            (pre + "attn_qkv_w", (d, 3 * d), 0.02),
            (pre + "attn_qkv_b", (3 * d,), 0.0),
            (pre + "attn_proj_w", (d, d), proj_std),
            (pre + "attn_proj_b", (d,), 0.0),
            (pre + "ln2_g", (d,), -1.0),
            (pre + "ln2_b", (d,), 0.0),
            (pre + "mlp_fc_w", (d, m), 0.02),
            (pre + "mlp_fc_b", (m,), 0.0),
            (pre + "mlp_proj_w", (m, d), proj_std),
            (pre + "mlp_proj_b", (d,), 0.0),
        ]
    specs += [("lnf_g", (d,), -1.0), ("lnf_b", (d,), 0.0)]
    return specs


def init_params(cfg: TransformerConfig, key) -> Dict[str, jnp.ndarray]:
    """Initialize parameters per :func:`param_specs`."""
    params = {}
    for name, shape, std in param_specs(cfg):
        if std < 0.0:
            params[name] = jnp.ones(shape, jnp.float32)
        elif std == 0.0:
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            key, sub = jax.random.split(key)
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attention(x, qkv_w, qkv_b, proj_w, proj_b, cfg: TransformerConfig):
    b, t, d = x.shape
    h, hd = cfg.n_head, cfg.head_dim
    qkv = matmul.matmul_bias(x.reshape(b * t, d), qkv_w, qkv_b).reshape(b, t, 3 * d)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b * t, d)
    return matmul.matmul_bias(out, proj_w, proj_b).reshape(b, t, d)


def _mlp(x, fc_w, fc_b, proj_w, proj_b):
    b, t, d = x.shape
    hidden = matmul.matmul_bias(x.reshape(b * t, d), fc_w, fc_b, gelu=True)
    return matmul.matmul_bias(hidden, proj_w, proj_b).reshape(b, t, d)


def transformer_loss(params: Dict[str, jnp.ndarray], tokens, cfg: TransformerConfig):
    """Mean next-token cross-entropy of the GPT on ``tokens`` (B, T+1)
    int32: positions 0..T-1 are inputs, 1..T are targets."""
    inp = tokens[:, :-1]
    tgt = tokens[:, 1:]
    b, t = inp.shape
    x = params["wte"][inp] + params["wpe"][jnp.arange(t)][None]
    for layer in range(cfg.n_layer):
        pre = f"h{layer}."
        x = x + _attention(
            _layer_norm(x, params[pre + "ln1_g"], params[pre + "ln1_b"]),
            params[pre + "attn_qkv_w"],
            params[pre + "attn_qkv_b"],
            params[pre + "attn_proj_w"],
            params[pre + "attn_proj_b"],
            cfg,
        )
        x = x + _mlp(
            _layer_norm(x, params[pre + "ln2_g"], params[pre + "ln2_b"]),
            params[pre + "mlp_fc_w"],
            params[pre + "mlp_fc_b"],
            params[pre + "mlp_proj_w"],
            params[pre + "mlp_proj_b"],
        )
    x = _layer_norm(x, params["lnf_g"], params["lnf_b"])
    logits = matmul.matmul(x.reshape(b * t, cfg.d_model), params["wte"].T)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt.reshape(b * t, 1), axis=-1)
    return jnp.mean(nll)


def transformer_loss_and_grads(flat_params: List[jnp.ndarray], tokens, cfg: TransformerConfig):
    """(loss, *grads) with params as the ordered flat list of
    :func:`param_specs` — the AOT entry point."""
    names = [name for name, _, _ in param_specs(cfg)]
    assert len(flat_params) == len(names)

    def loss_from_list(plist):
        return transformer_loss(dict(zip(names, plist)), tokens, cfg)

    loss, grads = jax.value_and_grad(loss_from_list)(list(flat_params))
    return (loss, *grads)
