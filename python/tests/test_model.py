"""L2 model correctness: shapes, gradients, learnability."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def cfg():
    # Small config so the test suite stays fast.
    return model.TransformerConfig(d_model=32, n_head=2, n_layer=1, d_mlp=64, seq_len=16, batch=4)


def test_quad_value_and_grad():
    x = jnp.asarray([1.0, 2.0], jnp.float32)
    a = jnp.asarray([3.0, -4.0], jnp.float32)
    b = jnp.asarray([0.0, 1.0], jnp.float32)
    v, g = model.quad_value_and_grad(x, a, b)
    assert float(v) == pytest.approx(3.0 * 1.0 + (-4.0) * 1.0)
    np.testing.assert_allclose(np.asarray(g), [6.0, -8.0])


def test_logistic_grad_matches_autodiff():
    rng = np.random.default_rng(0)
    m, d = 32, 8
    w = jnp.asarray(rng.standard_normal(d).astype(np.float32)) * 0.3
    x = jnp.asarray(rng.standard_normal((m, d)).astype(np.float32))
    y = jnp.asarray(np.sign(rng.standard_normal(m)).astype(np.float32))
    lam = jnp.float32(0.05)

    _, manual = model.logistic_value_and_grad(w, x, y, lam)

    def loss_only(w):
        return model.logistic_value_and_grad(w, x, y, lam)[0]

    auto = jax.grad(loss_only)(w)
    np.testing.assert_allclose(np.asarray(manual), np.asarray(auto), rtol=1e-4, atol=1e-5)


def test_logistic_zero_weights_loss_is_ln2():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((16, 4)).astype(np.float32))
    y = jnp.asarray(np.sign(rng.standard_normal(16)).astype(np.float32))
    loss, _ = model.logistic_value_and_grad(jnp.zeros(4), x, y, 0.0)
    assert float(loss) == pytest.approx(math.log(2.0), rel=1e-5)


def test_param_specs_count_and_order_stable(cfg):
    specs = model.param_specs(cfg)
    names = [n for n, _, _ in specs]
    assert names[0] == "wte" and names[1] == "wpe"
    assert names[-2:] == ["lnf_g", "lnf_b"]
    assert len(names) == 2 + 12 * cfg.n_layer + 2
    # deterministic across calls
    assert names == [n for n, _, _ in model.param_specs(cfg)]


def test_transformer_loss_near_uniform_at_init(cfg):
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len + 1)), jnp.int32)
    loss = model.transformer_loss(params, toks, cfg)
    assert abs(float(loss) - math.log(cfg.vocab)) < 0.3


def test_transformer_grads_shapes(cfg):
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    specs = model.param_specs(cfg)
    flat = [params[n] for n, _, _ in specs]
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len + 1)), jnp.int32)
    out = model.transformer_loss_and_grads(flat, toks, cfg)
    assert len(out) == 1 + len(specs)
    for g, (_, shape, _) in zip(out[1:], specs):
        assert g.shape == shape
    assert np.isfinite(float(out[0]))


def test_transformer_learns_bigram_structure():
    """A few SGD steps on deterministic successor data should push the
    loss well below uniform — the model (and its Pallas matmuls + VJPs)
    can actually learn."""
    # Small vocab so 50 plain-SGD steps are enough to show learning.
    lcfg = model.TransformerConfig(
        vocab=32, d_model=32, n_head=2, n_layer=1, d_mlp=64, seq_len=16, batch=8
    )
    params = model.init_params(lcfg, jax.random.PRNGKey(0))
    names = [n for n, _, _ in model.param_specs(lcfg)]
    flat = [params[n] for n in names]
    rng = np.random.default_rng(4)

    def batch():
        start = rng.integers(0, lcfg.vocab, lcfg.batch)
        seq = (start[:, None] + np.arange(lcfg.seq_len + 1)[None]) % lcfg.vocab
        return jnp.asarray(seq, jnp.int32)

    loss0 = None
    for step in range(50):
        out = model.transformer_loss_and_grads(flat, batch(), lcfg)
        if step == 0:
            loss0 = float(out[0])
        flat = [p - 1.0 * g for p, g in zip(flat, out[1:])]
    loss1 = float(model.transformer_loss_and_grads(flat, batch(), lcfg)[0])
    assert loss1 < loss0 * 0.5, f"loss {loss0} -> {loss1}"
