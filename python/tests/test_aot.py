"""AOT pipeline checks: manifest consistency and HLO-text lowering."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out", ART],
            check=True,
            cwd=os.path.join(os.path.dirname(__file__), ".."),
        )
    with open(path) as f:
        return json.load(f)


def test_manifest_lists_all_models(manifest):
    assert manifest["format_version"] == 1
    for name in ["quad", "logistic", "transformer", "quantize", "consensus"]:
        assert name in manifest["models"], name
        m = manifest["models"][name]
        assert os.path.exists(os.path.join(ART, m["hlo"])), m["hlo"]
        assert m["inputs"] and m["outputs"]


def test_hlo_text_is_parseable_hlo(manifest):
    for name, m in manifest["models"].items():
        text = open(os.path.join(ART, m["hlo"])).read()
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ENTRY" in text, name


def test_transformer_params_bin_matches_manifest(manifest):
    m = manifest["models"]["transformer"]
    total = m["params"]["total"]
    flat = np.fromfile(os.path.join(ART, m["params"]["file"]), np.float32)
    assert flat.size == total
    # Total must equal the sum of the declared param input sizes
    # (inputs minus the trailing tokens input).
    sizes = [int(np.prod(i["shape"])) for i in m["inputs"][:-1]]
    assert sum(sizes) == total
    assert m["inputs"][-1]["name"] == "tokens"
    assert m["inputs"][-1]["dtype"] == "s32"
    # ln gains initialized to ones, so the params can't be all ~N(0, .02).
    assert np.abs(flat).max() > 0.5


def test_output_grads_mirror_param_inputs(manifest):
    m = manifest["models"]["transformer"]
    param_inputs = m["inputs"][:-1]
    grad_outputs = m["outputs"][1:]
    assert len(param_inputs) == len(grad_outputs)
    for i, o in zip(param_inputs, grad_outputs):
        assert o["name"] == "d_" + i["name"]
        assert o["shape"] == i["shape"]
