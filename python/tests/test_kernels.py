"""L1 kernel correctness: Pallas vs pure-jnp oracle, swept over shapes
and values with hypothesis. This is the core correctness signal for the
compiled artifacts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import consensus, matmul, quantize, ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


# --------------------------------------------------------------------------
# quantize
# --------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    p=st.integers(min_value=1, max_value=10_000),
    scale=st.floats(min_value=0.01, max_value=100.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_stochastic_round_matches_ref(p, scale, seed):
    rng = np.random.default_rng(seed)
    z = jnp.asarray((rng.standard_normal(p) * scale).astype(np.float32))
    u = jnp.asarray(rng.random(p).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(quantize.stochastic_round(z, u)),
        np.asarray(ref.stochastic_round_ref(z, u)),
    )


@settings(**SETTINGS)
@given(
    p=st.integers(min_value=1, max_value=5_000),
    kg=st.floats(min_value=0.1, max_value=1000.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_amplified_round_matches_ref(p, kg, seed):
    rng = np.random.default_rng(seed)
    y = _rand(rng, p)
    u = jnp.asarray(rng.random(p).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(quantize.amplified_round(y, u, kg)),
        np.asarray(ref.amplified_round_ref(y, u, np.float32(kg))),
    )


def test_stochastic_round_is_unbiased():
    rng = np.random.default_rng(7)
    z = jnp.full((20_000,), 0.3, jnp.float32)
    u = jnp.asarray(rng.random(20_000).astype(np.float32))
    mean = float(jnp.mean(quantize.stochastic_round(z, u)))
    assert abs(mean - 0.3) < 0.02


def test_stochastic_round_integers_are_exact():
    z = jnp.asarray([0.0, 1.0, -5.0, 100.0], jnp.float32)
    u = jnp.asarray([0.5, 0.01, 0.99, 0.5], jnp.float32)
    np.testing.assert_array_equal(np.asarray(quantize.stochastic_round(z, u)), np.asarray(z))


# --------------------------------------------------------------------------
# consensus
# --------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.integers(min_value=1, max_value=8),
    p=st.integers(min_value=1, max_value=5_000),
    alpha=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_consensus_step_matches_ref(n, p, alpha, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, n, p)
    w = jnp.asarray(rng.random(n).astype(np.float32))
    g = _rand(rng, p)
    np.testing.assert_allclose(
        np.asarray(consensus.consensus_step(x, w, g, alpha)),
        np.asarray(ref.consensus_step_ref(x, w, g, np.float32(alpha))),
        rtol=1e-5,
        atol=1e-5,
    )


# --------------------------------------------------------------------------
# matmul
# --------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    m=st.integers(min_value=1, max_value=300),
    k=st.integers(min_value=1, max_value=128),
    n=st.integers(min_value=1, max_value=300),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, m, k)
    b = _rand(rng, k, n)
    np.testing.assert_allclose(
        np.asarray(matmul.matmul(a, b)),
        np.asarray(ref.matmul_ref(a, b)),
        rtol=1e-4,
        atol=1e-4,
    )


@settings(**SETTINGS)
@given(
    m=st.integers(min_value=1, max_value=200),
    k=st.integers(min_value=1, max_value=64),
    n=st.integers(min_value=1, max_value=200),
    gelu=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matmul_bias_matches_ref(m, k, n, gelu, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, m, k)
    b = _rand(rng, k, n)
    bias = _rand(rng, n)
    np.testing.assert_allclose(
        np.asarray(matmul.matmul_bias(a, b, bias, gelu=gelu)),
        np.asarray(ref.matmul_bias_ref(a, b, bias, gelu=gelu)),
        rtol=1e-4,
        atol=1e-4,
    )


@pytest.mark.parametrize("gelu", [False, True])
def test_matmul_bias_gradients_match_jnp(gelu):
    """custom_vjp backward (Pallas) vs autodiff through the jnp oracle."""
    rng = np.random.default_rng(3)
    a = _rand(rng, 37, 19)
    b = _rand(rng, 19, 23)
    bias = _rand(rng, 23)

    def pallas_loss(a, b, bias):
        return jnp.sum(jnp.sin(matmul.matmul_bias(a, b, bias, gelu=gelu)))

    def ref_loss(a, b, bias):
        return jnp.sum(jnp.sin(ref.matmul_bias_ref(a, b, bias, gelu=gelu)))

    gp = jax.grad(pallas_loss, argnums=(0, 1, 2))(a, b, bias)
    gr = jax.grad(ref_loss, argnums=(0, 1, 2))(a, b, bias)
    for x, y in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-4, atol=1e-4)


def test_matmul_gradients_match_jnp():
    rng = np.random.default_rng(4)
    a = _rand(rng, 40, 12)
    b = _rand(rng, 12, 31)

    def pallas_loss(a, b):
        return jnp.sum(matmul.matmul(a, b) ** 2)

    def ref_loss(a, b):
        return jnp.sum(ref.matmul_ref(a, b) ** 2)

    gp = jax.grad(pallas_loss, argnums=(0, 1))(a, b)
    gr = jax.grad(ref_loss, argnums=(0, 1))(a, b)
    for x, y in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-4, atol=1e-4)
